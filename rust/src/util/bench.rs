//! A criterion-style micro-benchmark harness for the `harness = false`
//! bench binaries (criterion itself is not available offline).
//!
//! Usage inside a bench binary:
//!
//! ```no_run
//! use memclos::util::bench::Bench;
//! let mut b = Bench::new("fig9");
//! b.iter("clos-1024", || { /* work */ });
//! b.report();
//! ```
//!
//! Each case is warmed up, then timed over enough iterations to reach a
//! target measurement time; median and median-absolute-deviation of the
//! per-iteration times are reported.

use std::time::{Duration, Instant};

/// One measured case.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Case label.
    pub name: String,
    /// Median per-iteration time.
    pub median: Duration,
    /// Median absolute deviation.
    pub mad: Duration,
    /// Iterations measured.
    pub iters: u64,
}

/// Bench harness accumulating measurements for one group.
pub struct Bench {
    group: String,
    warmup: Duration,
    target: Duration,
    min_samples: usize,
    results: Vec<Measurement>,
}

impl Bench {
    /// New group with default timing budget (0.3 s warmup, 1 s measure).
    pub fn new(group: &str) -> Self {
        // `cargo bench -- --quick` style override via env var.
        let quick = std::env::var("MEMCLOS_BENCH_QUICK").is_ok();
        Self {
            group: group.to_string(),
            warmup: if quick { Duration::from_millis(20) } else { Duration::from_millis(300) },
            target: if quick { Duration::from_millis(100) } else { Duration::from_secs(1) },
            min_samples: 10,
            results: Vec::new(),
        }
    }

    /// Override the measurement budget.
    pub fn budget(mut self, warmup: Duration, target: Duration) -> Self {
        self.warmup = warmup;
        self.target = target;
        self
    }

    /// Measure a closure; its return value is black-boxed.
    pub fn iter<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Measurement {
        // Warmup and estimate per-iteration cost.
        let start = Instant::now();
        let mut warm_iters = 0u64;
        while start.elapsed() < self.warmup || warm_iters == 0 {
            black_box(f());
            warm_iters += 1;
        }
        let per_iter = start.elapsed() / warm_iters.max(1) as u32;

        // Choose a sample count targeting the measurement budget.
        let samples = if per_iter.is_zero() {
            1000
        } else {
            ((self.target.as_nanos() / per_iter.as_nanos().max(1)) as usize)
                .clamp(self.min_samples, 100_000)
        };

        let mut times: Vec<f64> = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        let mut devs: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = devs[devs.len() / 2];

        self.results.push(Measurement {
            name: name.to_string(),
            median: Duration::from_secs_f64(median),
            mad: Duration::from_secs_f64(mad),
            iters: samples as u64,
        });
        self.results.last().unwrap()
    }

    /// Print the report table for the group.
    pub fn report(&self) {
        println!("\n== bench group: {} ==", self.group);
        let wname = self.results.iter().map(|m| m.name.len()).max().unwrap_or(4).max(4);
        println!("{:<wname$}  {:>14}  {:>12}  {:>8}", "case", "median", "+/- mad", "iters");
        for m in &self.results {
            println!(
                "{:<wname$}  {:>14}  {:>12}  {:>8}",
                m.name,
                fmt_duration(m.median),
                fmt_duration(m.mad),
                m.iters
            );
        }
    }

    /// Access the accumulated measurements.
    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

/// Opaque value sink preventing the optimizer from deleting the work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Human duration formatting (ns/us/ms/s).
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("MEMCLOS_BENCH_QUICK", "1");
        let mut b = Bench::new("test").budget(Duration::from_millis(1), Duration::from_millis(5));
        let m = b.iter("noop-ish", || (0..100).sum::<u64>());
        assert!(m.iters >= 10);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500 ns");
        assert_eq!(fmt_duration(Duration::from_micros(1500)), "1.50 ms");
        assert!(fmt_duration(Duration::from_secs(2)).ends_with(" s"));
    }
}
