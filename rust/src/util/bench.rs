//! A criterion-style micro-benchmark harness for the `harness = false`
//! bench binaries (criterion itself is not available offline).
//!
//! Usage inside a bench binary:
//!
//! ```no_run
//! use memclos::util::bench::Bench;
//! let mut b = Bench::new("fig9");
//! b.iter("clos-1024", || { /* work */ });
//! b.report();
//! ```
//!
//! Each case is warmed up, then timed over enough iterations to reach a
//! target measurement time; median and median-absolute-deviation of the
//! per-iteration times are reported.

use std::time::{Duration, Instant};

/// One measured case.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Case label.
    pub name: String,
    /// Median per-iteration time.
    pub median: Duration,
    /// Median absolute deviation.
    pub mad: Duration,
    /// Iterations measured.
    pub iters: u64,
    /// Items (e.g. addresses) processed per iteration; 0 when the case
    /// has no meaningful throughput.
    pub items: u64,
}

impl Measurement {
    /// Items per second (0.0 when `items` is 0).
    pub fn throughput(&self) -> f64 {
        if self.items == 0 || self.median.is_zero() {
            0.0
        } else {
            self.items as f64 / self.median.as_secs_f64()
        }
    }
}

/// Bench harness accumulating measurements for one group.
pub struct Bench {
    group: String,
    warmup: Duration,
    target: Duration,
    min_samples: usize,
    results: Vec<Measurement>,
}

impl Bench {
    /// New group with default timing budget (0.3 s warmup, 1 s measure).
    pub fn new(group: &str) -> Self {
        // `cargo bench -- --quick` style override via env var.
        let quick = std::env::var("MEMCLOS_BENCH_QUICK").is_ok();
        Self {
            group: group.to_string(),
            warmup: if quick { Duration::from_millis(20) } else { Duration::from_millis(300) },
            target: if quick { Duration::from_millis(100) } else { Duration::from_secs(1) },
            min_samples: 10,
            results: Vec::new(),
        }
    }

    /// Override the measurement budget.
    pub fn budget(mut self, warmup: Duration, target: Duration) -> Self {
        self.warmup = warmup;
        self.target = target;
        self
    }

    /// Measure a closure; its return value is black-boxed.
    pub fn iter<T, F: FnMut() -> T>(&mut self, name: &str, f: F) -> &Measurement {
        self.iter_items(name, 0, f)
    }

    /// Measure a closure that processes `items` items per iteration
    /// (recorded for throughput reporting / the JSON schema).
    pub fn iter_items<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        items: u64,
        mut f: F,
    ) -> &Measurement {
        // Warmup and estimate per-iteration cost.
        let start = Instant::now();
        let mut warm_iters = 0u64;
        while start.elapsed() < self.warmup || warm_iters == 0 {
            black_box(f());
            warm_iters += 1;
        }
        let per_iter = start.elapsed() / warm_iters.max(1) as u32;

        // Choose a sample count targeting the measurement budget.
        let samples = if per_iter.is_zero() {
            1000
        } else {
            ((self.target.as_nanos() / per_iter.as_nanos().max(1)) as usize)
                .clamp(self.min_samples, 100_000)
        };

        let mut times: Vec<f64> = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        let mut devs: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = devs[devs.len() / 2];

        self.results.push(Measurement {
            name: name.to_string(),
            median: Duration::from_secs_f64(median),
            mad: Duration::from_secs_f64(mad),
            iters: samples as u64,
            items,
        });
        self.results.last().unwrap()
    }

    /// Print the report table for the group.
    pub fn report(&self) {
        println!("\n== bench group: {} ==", self.group);
        let wname = self.results.iter().map(|m| m.name.len()).max().unwrap_or(4).max(4);
        println!("{:<wname$}  {:>14}  {:>12}  {:>8}", "case", "median", "+/- mad", "iters");
        for m in &self.results {
            println!(
                "{:<wname$}  {:>14}  {:>12}  {:>8}",
                m.name,
                fmt_duration(m.median),
                fmt_duration(m.mad),
                m.iters
            );
        }
    }

    /// Access the accumulated measurements.
    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Look up a measurement by case name.
    pub fn get(&self, name: &str) -> Option<&Measurement> {
        self.results.iter().find(|m| m.name == name)
    }

    /// Render the group in the machine-readable perf-trajectory schema:
    /// `{"bench": <group>, "results": [{"name", "median_ns",
    /// "addrs_per_s"}]}` (`addrs_per_s` is 0 for cases without a
    /// per-item throughput). Case names are plain ASCII identifiers, so
    /// no JSON escaping is required.
    pub fn to_json(&self) -> String {
        let mut s = format!("{{\"bench\": \"{}\", \"results\": [", self.group);
        for (i, m) in self.results.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!(
                "{{\"name\": \"{}\", \"median_ns\": {:.1}, \"addrs_per_s\": {:.0}}}",
                m.name,
                m.median.as_secs_f64() * 1e9,
                m.throughput(),
            ));
        }
        s.push_str("]}");
        s
    }

    /// Write [`Bench::to_json`] to `path`.
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json() + "\n")
    }
}

/// Opaque value sink preventing the optimizer from deleting the work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Human duration formatting (ns/us/ms/s).
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("MEMCLOS_BENCH_QUICK", "1");
        let mut b = Bench::new("test").budget(Duration::from_millis(1), Duration::from_millis(5));
        let m = b.iter("noop-ish", || (0..100).sum::<u64>());
        assert!(m.iters >= 10);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500 ns");
        assert_eq!(fmt_duration(Duration::from_micros(1500)), "1.50 ms");
        assert!(fmt_duration(Duration::from_secs(2)).ends_with(" s"));
    }

    #[test]
    fn items_give_throughput_and_json_schema() {
        let mut b = Bench::new("hotpath").budget(Duration::from_millis(1), Duration::from_millis(5));
        b.iter_items("native-65536", 65_536, || (0..1000).sum::<u64>());
        b.iter("exact-closed-form", || 1 + 1);
        let m = b.get("native-65536").unwrap();
        assert_eq!(m.items, 65_536);
        assert!(m.throughput() > 0.0);
        assert_eq!(b.get("exact-closed-form").unwrap().throughput(), 0.0);
        assert!(b.get("missing").is_none());

        let json = b.to_json();
        assert!(json.starts_with("{\"bench\": \"hotpath\", \"results\": ["));
        assert!(json.contains("\"name\": \"native-65536\""));
        assert!(json.contains("\"median_ns\": "));
        assert!(json.contains("\"addrs_per_s\": "));
        assert!(json.ends_with("]}"));
        // The schema must parse as JSON (spot-check balance).
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }
}
