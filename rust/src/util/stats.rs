//! Summary statistics and histograms for measurement aggregation.

/// Streaming summary: count, mean, variance (Welford), min/max.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Add one observation.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Add every value in a slice.
    pub fn add_all(&mut self, xs: &[f64]) {
        for &x in xs {
            self.add(x);
        }
    }

    /// Build a summary from a slice.
    pub fn of(xs: &[f64]) -> Self {
        let mut s = Self::new();
        s.add_all(xs);
        s
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Arithmetic mean (0 for an empty summary).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another summary into this one (parallel aggregation).
    pub fn merge(&mut self, o: &Summary) {
        if o.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = o.clone();
            return;
        }
        let n = (self.n + o.n) as f64;
        let d = o.mean - self.mean;
        self.m2 += o.m2 + d * d * (self.n as f64 * o.n as f64) / n;
        self.mean += d * o.n as f64 / n;
        self.n += o.n;
        self.min = self.min.min(o.min);
        self.max = self.max.max(o.max);
    }
}

/// Percentile of a slice (linear interpolation, `p` in [0, 100]).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "input must be sorted");
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p.clamp(0.0, 100.0) / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Median of an unsorted slice (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile(&v, 50.0)
}

/// Order statistics of one sample: the tail-latency quantities the
/// contention reports carry (mean/p50/p95/p99/max). The mean is the
/// streaming [`Summary`] mean, so it compares bitwise against summaries
/// built from the same observations in the same order.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Dist {
    /// Number of observations.
    pub count: u64,
    /// Arithmetic mean.
    pub mean: f64,
    /// Median.
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile.
    pub p99: f64,
    /// Largest observation.
    pub max: f64,
}

impl Dist {
    /// Distribution of a sample (all zeros for an empty slice).
    pub fn of(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return Self::default();
        }
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self {
            count: xs.len() as u64,
            mean: Summary::of(xs).mean(),
            p50: percentile(&v, 50.0),
            p95: percentile(&v, 95.0),
            p99: percentile(&v, 99.0),
            max: *v.last().unwrap(),
        }
    }
}

/// Fixed-bin histogram over `[lo, hi)`.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    under: u64,
    over: u64,
}

impl Histogram {
    /// Histogram with `bins` equal-width bins over `[lo, hi)`.
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Self { lo, hi, bins: vec![0; bins], under: 0, over: 0 }
    }

    /// Record one observation.
    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.under += 1;
        } else if x >= self.hi {
            self.over += 1;
        } else {
            let nbins = self.bins.len();
            let i = ((x - self.lo) / (self.hi - self.lo) * nbins as f64) as usize;
            self.bins[i.min(nbins - 1)] += 1;
        }
    }

    /// Bin counts.
    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    /// Observations below `lo` / at-or-above `hi`.
    pub fn outliers(&self) -> (u64, u64) {
        (self.under, self.over)
    }

    /// Total observations, including outliers.
    pub fn count(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.under + self.over
    }

    /// Midpoint of bin `i`.
    pub fn bin_center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.lo + w * (i as f64 + 0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_mean_var() {
        let s = Summary::of(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn summary_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let whole = Summary::of(&xs);
        let mut a = Summary::of(&xs[..37]);
        let b = Summary::of(&xs[37..]);
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.variance() - whole.variance()).abs() < 1e-9);
        assert_eq!(a.count(), whole.count());
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut s = Summary::of(&[1.0, 2.0, 3.0]);
        let before = (s.mean(), s.variance());
        s.merge(&Summary::new());
        assert_eq!((s.mean(), s.variance()), before);
        let mut e = Summary::new();
        e.merge(&s);
        assert!((e.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_endpoints_and_median() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert!((percentile(&v, 50.0) - 2.5).abs() < 1e-12);
        assert!((median(&[3.0, 1.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn dist_orders_the_tail() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let d = Dist::of(&xs);
        assert_eq!(d.count, 100);
        assert!((d.mean - 50.5).abs() < 1e-12);
        assert!((d.p50 - 50.5).abs() < 1e-12);
        assert!(d.p95 <= d.p99 && d.p99 <= d.max);
        assert_eq!(d.max, 100.0);
        // The mean matches a Summary over the same stream bit for bit.
        assert_eq!(d.mean.to_bits(), Summary::of(&xs).mean().to_bits());
        assert_eq!(Dist::of(&[]), Dist::default());
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(42.0);
        assert!(h.bins().iter().all(|&b| b == 1));
        assert_eq!(h.outliers(), (1, 1));
        assert_eq!(h.count(), 12);
        assert!((h.bin_center(0) - 0.5).abs() < 1e-12);
    }
}
