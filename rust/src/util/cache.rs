//! A shared concurrent memo cache with LRU eviction — the
//! [`crate::coordinator::ParallelSweep`] result cache generalised so the
//! serve layer ([`crate::serve`]) and any future consumer key results by
//! a canonical encoding and share them across threads.
//!
//! Design:
//!
//! * **One mutex, whole-value entries.** Values are inserted whole and
//!   cloned out whole, so a panic elsewhere can never leave an entry
//!   half-written — locks recover from poisoning
//!   ([`std::sync::PoisonError::into_inner`]) for the same reason the
//!   sweep caches always did: the data behind a poisoned lock is still
//!   valid, and refusing to serve it would turn one caught panic into a
//!   permanently dead cache.
//! * **Transactional access.** [`LruCache::with`] runs a closure under
//!   the lock over a [`CacheView`], so multi-step read-modify-write
//!   protocols (the sweep engine's scan-then-insert-then-assemble) stay
//!   atomic and control their own hit/miss accounting. The convenience
//!   [`LruCache::get`]/[`LruCache::insert`] wrappers cover the common
//!   single-key case.
//! * **Bounded, with counters.** `max_entries`/`max_bytes` caps (0 =
//!   unbounded) evict least-recently-used entries on insert; hits,
//!   misses and evictions are reported via [`LruCache::stats`]. A
//!   single entry larger than `max_bytes` is admitted alone (a cache
//!   that can hold nothing would turn every request into a miss loop).
//!
//! Eviction scans for the oldest entry in O(len). The caches this crate
//! needs hold at most a few thousand entries, where the scan is cheaper
//! than maintaining an intrusive list; revisit if a cache ever grows
//! past ~10^5 entries.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

/// Cache effectiveness counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that found nothing (or chose to evaluate fresh).
    pub misses: u64,
    /// Entries evicted to respect the capacity/byte bounds.
    pub evictions: u64,
}

struct Entry<V> {
    value: V,
    bytes: usize,
    /// Last-touch tick (monotone per cache) — the LRU order.
    last: u64,
}

struct Inner<K, V> {
    map: HashMap<K, Entry<V>>,
    bytes: usize,
    tick: u64,
}

/// The shared concurrent LRU cache.
pub struct LruCache<K, V> {
    inner: Mutex<Inner<K, V>>,
    max_entries: usize,
    max_bytes: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

/// The view a [`LruCache::with`] closure operates on: every method runs
/// under the cache lock, so a whole closure is one atomic transaction.
pub struct CacheView<'a, K, V> {
    guard: MutexGuard<'a, Inner<K, V>>,
    cache: &'a LruCache<K, V>,
}

impl<K: Eq + Hash + Clone, V: Clone> LruCache<K, V> {
    /// A cache bounded to `max_entries` entries and `max_bytes` payload
    /// bytes (0 = unbounded in that dimension).
    pub fn bounded(max_entries: usize, max_bytes: usize) -> Self {
        Self {
            inner: Mutex::new(Inner { map: HashMap::new(), bytes: 0, tick: 0 }),
            max_entries,
            max_bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// An unbounded cache (what the sweep engine's memo caches use:
    /// their key space is the finite set of design points one process
    /// evaluates).
    pub fn unbounded() -> Self {
        Self::bounded(0, 0)
    }

    fn lock(&self) -> MutexGuard<'_, Inner<K, V>> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Run `f` under the cache lock. Everything the closure does via the
    /// [`CacheView`] — lookups, inserts, hit/miss accounting — is one
    /// atomic transaction against concurrent callers.
    pub fn with<R>(&self, f: impl FnOnce(&mut CacheView<'_, K, V>) -> R) -> R {
        let mut view = CacheView { guard: self.lock(), cache: self };
        f(&mut view)
    }

    /// Counted single-key lookup (hit or miss recorded; a hit refreshes
    /// the entry's LRU position).
    pub fn get(&self, key: &K) -> Option<V> {
        self.with(|c| c.get(key))
    }

    /// Insert with an explicit payload weight in bytes, evicting LRU
    /// entries as needed. Zero-weight entries only count against
    /// `max_entries`.
    pub fn insert_weighted(&self, key: K, value: V, bytes: usize) {
        self.with(|c| c.insert(key, value, bytes));
    }

    /// Insert a zero-weight entry (see [`LruCache::insert_weighted`]).
    pub fn insert(&self, key: K, value: V) {
        self.insert_weighted(key, value, 0);
    }

    /// Counters so far.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }

    /// Entries currently held.
    pub fn len(&self) -> usize {
        self.lock().map.len()
    }

    /// True when the cache holds nothing.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Payload bytes currently held (the sum of insert weights).
    pub fn bytes(&self) -> usize {
        self.lock().bytes
    }

    /// Drop every entry (counters are kept).
    pub fn clear(&self) {
        let mut inner = self.lock();
        inner.map.clear();
        inner.bytes = 0;
    }
}

impl<K: Eq + Hash + Clone, V: Clone> CacheView<'_, K, V> {
    /// Uncounted membership probe (no hit/miss recorded, no LRU touch) —
    /// for protocols that account hits themselves, like the sweep
    /// engine's duplicate scan.
    pub fn contains(&self, key: &K) -> bool {
        self.guard.map.contains_key(key)
    }

    /// Counted lookup: records a hit or miss and refreshes the entry's
    /// LRU position.
    pub fn get(&mut self, key: &K) -> Option<V> {
        match self.fetch(key) {
            Some(v) => {
                self.note_hit();
                Some(v)
            }
            None => {
                self.note_miss();
                None
            }
        }
    }

    /// Uncounted lookup (LRU position is still refreshed).
    pub fn fetch(&mut self, key: &K) -> Option<V> {
        self.guard.tick += 1;
        let tick = self.guard.tick;
        let entry = self.guard.map.get_mut(key)?;
        entry.last = tick;
        Some(entry.value.clone())
    }

    /// Insert (or replace) an entry with a payload weight of `bytes`,
    /// then evict least-recently-used entries until the cache respects
    /// its bounds again. The just-inserted entry is never evicted
    /// unless it alone exceeds `max_entries == 0` semantics (it is the
    /// most recently used by construction).
    pub fn insert(&mut self, key: K, value: V, bytes: usize) {
        self.guard.tick += 1;
        let tick = self.guard.tick;
        if let Some(old) = self.guard.map.insert(key, Entry { value, bytes, last: tick }) {
            self.guard.bytes -= old.bytes;
        }
        self.guard.bytes += bytes;
        let max_entries = self.cache.max_entries;
        let max_bytes = self.cache.max_bytes;
        while self.guard.map.len() > 1
            && ((max_entries > 0 && self.guard.map.len() > max_entries)
                || (max_bytes > 0 && self.guard.bytes > max_bytes))
        {
            self.evict_lru();
        }
    }

    /// Record a hit the caller resolved without touching the map (e.g.
    /// an intra-call duplicate that will be served by a later insert).
    pub fn note_hit(&mut self) {
        self.cache.hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a miss the caller resolved by evaluating fresh.
    pub fn note_miss(&mut self) {
        self.cache.misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Entries currently held.
    pub fn len(&self) -> usize {
        self.guard.map.len()
    }

    /// True when the cache holds nothing.
    pub fn is_empty(&self) -> bool {
        self.guard.map.is_empty()
    }

    fn evict_lru(&mut self) {
        let oldest = self
            .guard
            .map
            .iter()
            .min_by_key(|(_, e)| e.last)
            .map(|(k, _)| k.clone());
        if let Some(k) = oldest {
            if let Some(e) = self.guard.map.remove(&k) {
                self.guard.bytes -= e.bytes;
                self.cache.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_insert_and_counters() {
        let c: LruCache<u64, String> = LruCache::unbounded();
        assert_eq!(c.get(&1), None);
        c.insert(1, "one".to_string());
        assert_eq!(c.get(&1).as_deref(), Some("one"));
        assert_eq!(c.stats(), CacheStats { hits: 1, misses: 1, evictions: 0 });
        assert_eq!(c.len(), 1);
        assert!(!c.is_empty());
    }

    #[test]
    fn entry_bound_evicts_least_recently_used() {
        let c: LruCache<u64, u64> = LruCache::bounded(2, 0);
        c.insert(1, 10);
        c.insert(2, 20);
        assert_eq!(c.get(&1), Some(10)); // refresh 1 → 2 is now LRU
        c.insert(3, 30);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&2), None, "LRU entry evicted");
        assert_eq!(c.get(&1), Some(10));
        assert_eq!(c.get(&3), Some(30));
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn byte_bound_evicts_and_replacement_updates_weight() {
        let c: LruCache<u64, Vec<u8>> = LruCache::bounded(0, 100);
        c.insert_weighted(1, vec![0; 40], 40);
        c.insert_weighted(2, vec![0; 40], 40);
        assert_eq!(c.bytes(), 80);
        c.insert_weighted(3, vec![0; 40], 40); // 120 > 100 → evict key 1
        assert_eq!(c.bytes(), 80);
        assert_eq!(c.get(&1), None);
        // Replacing a key swaps its weight, not adds it.
        c.insert_weighted(2, vec![0; 10], 10);
        assert_eq!(c.bytes(), 50);
    }

    #[test]
    fn an_oversized_sole_entry_is_admitted() {
        let c: LruCache<u64, Vec<u8>> = LruCache::bounded(0, 10);
        c.insert_weighted(1, vec![0; 64], 64);
        assert_eq!(c.len(), 1, "a cache that can hold nothing would never hit");
        c.insert_weighted(2, vec![0; 64], 64);
        assert_eq!(c.len(), 1, "the older oversized entry is evicted");
        assert_eq!(c.get(&2).map(|v| v.len()), Some(64));
    }

    #[test]
    fn with_transaction_controls_its_own_accounting() {
        // The sweep-engine protocol: probe untracked, account manually,
        // insert, then assemble with uncounted fetches.
        let c: LruCache<u64, u64> = LruCache::unbounded();
        c.with(|view| {
            assert!(!view.contains(&7));
            view.note_miss();
            view.insert(7, 49, 0);
            assert!(view.contains(&7));
            view.note_hit();
            assert_eq!(view.fetch(&7), Some(49)); // uncounted
        });
        assert_eq!(c.stats(), CacheStats { hits: 1, misses: 1, evictions: 0 });
    }

    #[test]
    fn survives_a_poisoned_lock() {
        let c = std::sync::Arc::new(LruCache::<u64, u64>::unbounded());
        c.insert(1, 11);
        let c2 = std::sync::Arc::clone(&c);
        let _ = std::thread::spawn(move || {
            c2.with(|view| {
                view.insert(2, 22, 0);
                panic!("poison the lock");
            })
        })
        .join();
        // Entries inserted whole are still valid behind the poison.
        assert_eq!(c.get(&1), Some(11));
        assert_eq!(c.get(&2), Some(22));
        c.insert(3, 33);
        assert_eq!(c.get(&3), Some(33));
    }
}
