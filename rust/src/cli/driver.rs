//! The command driver: every `memclos` subcommand, runnable from the
//! binary (`main` is a thin shim) and from integration tests (which
//! call [`run`] directly and assert on [`super::exit_code`]).
//!
//! All commands build design points through [`crate::api`]'s
//! [`DesignPoint`] builder (paper defaults + `--set`/`--config`
//! overrides + CLI flags, in that precedence order) and evaluate
//! latency on the [`crate::coordinator`] sweep engine. Misuse —
//! unknown command, malformed flag, unreadable config — is a typed
//! [`super::UsageError`] (exit code 2); runtime failures keep exit
//! code 1.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::{usage_error, Args};
use crate::api::{DesignPoint, Mode, Report, Row, Tech, XlaBackend};
use crate::cc::{compile, Backend};
use crate::config::{self, Doc};
use crate::coordinator::{default_jobs, SweepPoint};
use crate::dram::{measure_random_latency, DramConfig};
use crate::emulation::{EmulationSetup, SequentialMachine, TopologyKind};
use crate::fault::FaultPlan;
use crate::figures::{self, FigOpts};
use crate::isa::decode::{predecode, FastMachine};
use crate::isa::inst::Inst;
use crate::isa::jit::{self, JitMachine};
use crate::isa::interp::{
    DirectMemory, EmulatedChannelMemory, ExecCursor, Machine, MachineState, MemorySystem,
    RunOutcome, RunStats,
};
use crate::isa::snapshot::{
    program_fingerprint, rebuild_memory, run_fast_slice, run_jit_slice, run_legacy_slice,
    BackendSnap, RebuiltMemory, Snapshot, Tier,
};
use crate::serve::{
    install_sigint, sigint_seen, LoadgenOpts, ServeConfig, Server, ServerConfig, Service,
};
use crate::sim::contention::{run_scenario, Workload};
use crate::topology::{ClosSpec, MeshSpec};
use crate::vlsi::{ClosFloorplan, MeshFloorplan};

const HELP: &str = "\
memclos — emulating a large memory with a collection of smaller ones

USAGE: memclos <command> [options]

COMMANDS
  tables [--which 1..5]         regenerate the paper's parameter tables
  figure <5|6|7|9|10|11|bsize|ablations|contention|faults|scale>  regenerate a figure / extension
  figures --all [--jobs N]      regenerate EVERY table and figure on one
                                shared sweep engine (repeated design
                                points evaluated once); --json emits the
                                machine-diffable reports the golden
                                harness pins, --out DIR writes them
  dram [--ranks N]              measure DDR3 random-access latency
  area --topo clos|mesh [--tiles N --mem KB]   floorplan one chip
  latency [--topo ... --tiles N --mem KB --k N]
                                emulated-memory latency for one point,
                                evaluated on the selected backend
  run <program> [--topo ...]    compile+run a corpus program on both machines
    --tier auto|jit|fast|legacy execution tier (default auto: the
                                baseline JIT where the host supports
                                it, else the pre-decoded fast loop;
                                `--tier jit` on an unsupported host is
                                a typed runtime error). --legacy is the
                                old spelling of --tier legacy
  contention [--clients N]...   trace-driven DES contention lab: replay a
                                clients x pattern grid, one DES timeline
                                per cell fanned out over --jobs; reports
                                mean/p50/p95/p99/max, queue waiting and
                                the fitted c_cont per cell
    --pattern P  (repeatable)   uniform | zipf[:theta] | stride[:words]
                                | chase | phased[:phases[:frac]]
                                (default uniform — bitwise the legacy
                                single-scenario experiment)
    --trace PROG (repeatable)   capture PROG's emulated-memory accesses
                                from a FastMachine run and replay them
                                (heterogeneous clients when repeated;
                                overrides --pattern)
  faults [--jobs N]             fault-injection figure: replay the trace
                                catalogue under seed-deterministic fault
                                plans (0-10% dead tiles, degraded/flaky
                                links, failed ports) and report slowdown,
                                p99 tail inflation, retries and timeouts
                                vs the healthy baseline; --json emits the
                                golden-pinned report
  serve [--addr HOST:PORT]      multi-tenant batched evaluation service:
                                length-prefixed JSON over TCP, shared
                                result cache, request batching, typed
                                overload sheds, graceful drain on SIGINT
                                or a `shutdown` request
    --port-file PATH            write the bound port (for scripts with
                                --addr 127.0.0.1:0)
    --cache-entries N / --cache-bytes N   result-cache bounds (0 = off)
    --linger-us N / --batch-max N         batcher window / batch cap
    --queue-depth N / --session-inflight N / --net-workers N
                                admission-control bounds
  loadgen --addr HOST:PORT      closed-loop load generator against a
                                running serve; writes BENCH_serve.json
    --clients N --requests N    load shape (per-client closed loop)
    --shutdown                  end the run by draining the server
    --self-host                 start an in-process server on an
                                ephemeral port, drive it, drain it
    --out PATH                  write the BENCH_serve.json report
  fuzz [--cases N --seed S]     generative differential fuzzing: typed
                                random miniC programs run on every
                                execution tier x both memory backends,
                                with a snapshot-slice resume oracle
                                every 16th case; divergences are
                                greedily shrunk (--no-shrink to skip)
                                and written as replayable artifacts
    --out DIR                   artifact directory (default .)
    --max-failures N            stop after N divergences (default 5)
    --replay PATH               re-run one artifact (conflicts with
                                --cases)
  snapshot save --program NAME --at CYCLES
                                pause a corpus program at a cycle budget
                                and write its complete machine state
                                (versioned, checksummed binary)
    --backend direct|emulated   memory backend (default emulated)
    --legacy                    snapshot the legacy enum-match machine
    --topo/--tiles/--mem/--k    emulated design point (defaults
                                clos/256/64/128)
    --out FILE                  snapshot path (default NAME.snap)
  snapshot resume --in FILE     resume a snapshot to completion
    --verify                    also rerun uninterrupted from cycle 0
                                and assert bit-identical stats+registers
  selfcheck                     prove XLA artifact == native model
  sweep --tiles N --mem KB      latency sweep over emulation sizes
  bench-hotpath [--out PATH]    measure the access hot path, write BENCH_hotpath.json
  bench-interp [--out PATH]     measure decoded-vs-legacy interpretation
                                over the cc corpus, write BENCH_interp.json
  bench-jit [--out PATH]        measure the baseline JIT tier over the cc
                                corpus, write BENCH_jit.json (empty
                                result set on hosts without the JIT)

BACKENDS (--mode, default auto)
  auto     XLA when artifacts/ holds the lowered kernel, else native MC
  exact    closed-form expectation (O(k), no sampling)
  native   native Monte-Carlo over the rank-latency LUT
  xla      Monte-Carlo on the AOT-compiled PJRT kernel
  des      Monte-Carlo through the discrete-event network simulator

COMMON OPTIONS
  --mode auto|exact|native|xla|des   evaluation backend (see above)
  --samples N                   Monte-Carlo samples (default 65536)
  --batch N                     XLA artifact batch size (default 16384)
  --jobs N                      sweep worker threads (default: available
                                parallelism; 1 forces the sequential
                                oracle — bit-identical output either
                                way; --workers is an alias)
  --seed N                      RNG seed
  --set key=value               config override (repeatable); system.*,
                                net.*, chip.*, interposer.* reach every
                                command, including the figures
  --fault-frac F                inject a seed-deterministic fault plan at
                                fraction F (dead tiles, degraded + flaky
                                links, failed ports) into the design
                                point; 0 is bitwise the healthy system
  --fault-seed N                fault-plan draw seed (default 0xFA17);
                                independent of --seed so the same plan
                                can be replayed under fresh workloads
  --config PATH                 config file (TOML subset)
  --json                        latency/sweep/contention: emit the
                                BENCH_hotpath.json schema family instead
                                of tables
";

/// Binary entry point: run the process arguments and map failure to the
/// typed exit code (2 = misuse, 1 = runtime failure).
pub fn main_entry() -> i32 {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            super::exit_code(&e)
        }
    }
}

fn eval_mode(args: &Args) -> Result<Mode> {
    let samples: usize = args.get("samples", 65_536)?;
    let batch: usize = args.get("batch", 16_384)?;
    Mode::parse(args.flag("mode"), samples, batch)
}

fn fig_opts(args: &Args, doc: &Doc) -> Result<FigOpts> {
    // `--jobs` is the flag; `--workers` survives as an alias.
    let workers: usize = args.get("workers", default_jobs())?;
    Ok(FigOpts {
        mode: eval_mode(args)?,
        jobs: args.get("jobs", workers)?,
        seed: args.get("seed", 0xC105)?,
        tech: Tech::from_doc(doc),
    })
}

/// The execution tier `memclos run` resolved from `--tier`/`--legacy`.
#[derive(Clone, Copy, PartialEq, Eq)]
enum RunTier {
    Legacy,
    Fast,
    Jit,
}

fn kind_str(kind: TopologyKind) -> &'static str {
    match kind {
        TopologyKind::Clos => "clos",
        TopologyKind::Mesh => "mesh",
    }
}

/// One design point from (in rising precedence) per-command defaults,
/// the config doc and explicit CLI flags.
fn design_point(
    args: &Args,
    doc: &Doc,
    default_tiles: usize,
    default_k: Option<usize>,
) -> Result<DesignPoint> {
    let mut dp = DesignPoint::clos(default_tiles).with_doc(doc)?;
    if let Some(k) = default_k {
        if doc.get("system.k").is_none() {
            dp = dp.k(k);
        }
    }
    if let Some(t) = args.flag("topo") {
        dp = dp.topology(TopologyKind::parse(t).map_err(|e| usage_error(format!("{e:#}")))?);
    }
    if args.flag("tiles").is_some() {
        dp = dp.tiles(args.get("tiles", 0usize)?);
    }
    if args.flag("mem").is_some() {
        dp = dp.mem_kb(args.get("mem", 0u32)?);
    }
    if args.flag("k").is_some() {
        dp = dp.k(args.get("k", 0usize)?);
    }
    if args.flag("fault-frac").is_some() {
        let frac: f64 = args.get("fault-frac", 0.0f64)?;
        let fault_seed: u64 = args.get("fault-seed", 0xFA17u64)?;
        dp = dp.faults(FaultPlan::fraction(frac, fault_seed));
    }
    Ok(dp)
}

/// Run one command line (without argv[0]). Integration tests call this
/// directly and map errors through [`super::exit_code`].
pub fn run(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw)?;
    if args.command.is_empty() || args.has("help") || args.command == "help" {
        println!("{HELP}");
        return Ok(());
    }
    // An unreadable or unparseable --config / --set is caller misuse;
    // flatten the chain so the exit-2 contract keeps the full story.
    let doc = config::load(args.flag("config").map(std::path::Path::new), &args.flag_all("set"))
        .map_err(|e| usage_error(format!("{e:#}")))?;
    let tech = Tech::from_doc(&doc);

    match args.command.as_str() {
        "tables" => {
            let which = args.flag("which");
            match which {
                None => print!("{}", figures::tables::render_all(&tech)),
                Some("1") => print!("{}", figures::tables::table1(&tech.chip).render()),
                Some("2") => print!("{}", figures::tables::table2(&tech.ip).render()),
                Some("3") => print!("{}", figures::tables::table3().render()),
                Some("4") => print!("{}", figures::tables::table4().render()),
                Some("5") => print!("{}", figures::tables::table5(&tech.net).render()),
                Some(o) => return Err(usage_error(format!("no table {o} (1..5)"))),
            }
        }
        "figure" => {
            let which = args
                .positional
                .first()
                .ok_or_else(|| usage_error("figure number required"))?;
            let opts = fig_opts(&args, &doc)?;
            let engine = opts.engine();
            match which.as_str() {
                "5" => print!(
                    "{}",
                    figures::fig5::render(&figures::fig5::generate_with(&engine)?, &opts.tech.chip)
                ),
                "6" => print!("{}", figures::fig6::render(&figures::fig6::generate_with(&engine)?)),
                "7" => print!("{}", figures::fig7::render(&figures::fig7::generate_with(&engine)?)),
                "9" => print!("{}", figures::fig9::render(&figures::fig9::generate_with(&engine)?)),
                "10" => print!("{}", figures::fig10::render(&figures::fig10::generate_with(&engine)?)),
                "11" => print!("{}", figures::fig11::render(&figures::fig11::generate_with(&engine)?)),
                "bsize" => print!("{}", figures::binary_size::render(&figures::binary_size::generate()?)),
                "ablations" => {
                    print!("{}", figures::ablations::render(&figures::ablations::generate_with(&engine)?))
                }
                "contention" => {
                    print!("{}", figures::contention::render(&figures::contention::generate_with(&engine)?))
                }
                "faults" => {
                    print!("{}", figures::faults::render(&figures::faults::generate_with(&engine)?))
                }
                "scale" => {
                    print!("{}", figures::scale::render(&figures::scale::generate_with(&engine)?))
                }
                o => {
                    return Err(usage_error(format!(
                        "no figure {o} (5|6|7|9|10|11|bsize|ablations|contention|faults|scale)"
                    )))
                }
            }
        }
        "figures" => {
            // The scenario-diversity payoff of the sweep engine: one
            // invocation regenerates the paper's entire evaluation on
            // one shared engine, so design points repeated across
            // figures (figs 9/10/11 share their sweeps, figs 5/6 their
            // floorplans) are evaluated once.
            if let Some(p) = args.positional.first() {
                return Err(usage_error(format!(
                    "`figures` takes no figure number (did you mean `figure {p}`?)"
                )));
            }
            if !args.has("all") {
                return Err(usage_error(
                    "`figures` regenerates everything — confirm with `figures --all`",
                ));
            }
            let opts = fig_opts(&args, &doc)?;
            let engine = opts.engine();
            if args.has("json") || args.flag("out").is_some() {
                let reports = figures::all_reports(&engine)?;
                if let Some(dir) = args.flag("out") {
                    let dir = std::path::Path::new(dir);
                    std::fs::create_dir_all(dir)
                        .with_context(|| format!("creating {}", dir.display()))?;
                    for r in &reports {
                        let path = dir.join(format!("{}.json", r.bench()));
                        r.write(&path).with_context(|| format!("writing {}", path.display()))?;
                    }
                    eprintln!("wrote {} reports to {}", reports.len(), dir.display());
                }
                if args.has("json") {
                    for r in &reports {
                        print!("{}", r.render());
                    }
                }
            } else {
                print!("{}", figures::tables::render_all(&opts.tech));
                print!(
                    "{}",
                    figures::fig5::render(&figures::fig5::generate_with(&engine)?, &opts.tech.chip)
                );
                print!("{}", figures::fig6::render(&figures::fig6::generate_with(&engine)?));
                print!("{}", figures::fig7::render(&figures::fig7::generate_with(&engine)?));
                print!("{}", figures::fig9::render(&figures::fig9::generate_with(&engine)?));
                print!("{}", figures::fig10::render(&figures::fig10::generate_with(&engine)?));
                print!("{}", figures::fig11::render(&figures::fig11::generate_with(&engine)?));
                print!("{}", figures::binary_size::render(&figures::binary_size::generate()?));
                print!("{}", figures::ablations::render(&figures::ablations::generate_with(&engine)?));
                print!("{}", figures::contention::render(&figures::contention::generate_with(&engine)?));
                print!("{}", figures::faults::render(&figures::faults::generate_with(&engine)?));
                print!("{}", figures::scale::render(&figures::scale::generate_with(&engine)?));
            }
            let cs = engine.cache_stats();
            eprintln!(
                "sweep engine: {} jobs, {} evaluations, {} cache hits",
                engine.jobs(),
                cs.misses,
                cs.hits
            );
        }
        "dram" => {
            let ranks: usize = args.get("ranks", 1)?;
            let n: u64 = args.get("samples", 20_000u64)?;
            let m = measure_random_latency(DramConfig::with_ranks(ranks), n, args.get("seed", 7)?)?;
            println!(
                "DDR3-1600 {} rank(s), {} GB: avg {:.2} ns (min {:.2}, max {:.2}, sd {:.2}) over {} accesses",
                ranks,
                m.config.capacity_bytes() >> 30,
                m.avg_ns,
                m.min_ns,
                m.max_ns,
                m.stddev_ns,
                m.accesses
            );
        }
        "area" => {
            let dp = design_point(&args, &doc, 256, None)?;
            let tiles = dp.system_tiles();
            let mem = dp.tile_mem_kb();
            match dp.kind() {
                TopologyKind::Clos => {
                    let fp = ClosFloorplan::plan(&ClosSpec::with_tiles(tiles), mem, &tech.chip)?;
                    println!(
                        "folded-Clos chip: {} tiles x {} KB\n  area {:.1} mm^2 ({:.1} x {:.1}), I/O {:.1} mm^2, switches {:.2} mm^2, wires {:.2} mm^2\n  wires: tile {:.2} mm ({} cy), edge-core {:.2} mm ({} cy), core-pad {:.2} mm ({} cy)\n  economical: {}",
                        fp.tiles, fp.mem_kb, fp.area_mm2, fp.chip_w_mm, fp.chip_h_mm,
                        fp.io_area_mm2, fp.switch_area_mm2, fp.wire_area_mm2,
                        fp.wire_tile_mm, fp.cycles.tile,
                        fp.wire_edge_core_mm, fp.cycles.edge_core,
                        fp.wire_core_pad_mm, fp.cycles.core_pad,
                        fp.is_economical(&tech.chip),
                    );
                }
                TopologyKind::Mesh => {
                    let fp = MeshFloorplan::plan(&MeshSpec::with_tiles(tiles), mem, &tech.chip)?;
                    println!(
                        "2D-mesh chip: {} tiles x {} KB\n  area {:.1} mm^2 (side {:.1}), I/O {:.1} mm^2, switches {:.2} mm^2, wires {:.2} mm^2\n  wires: tile {:.2} mm ({} cy), hop {:.2} mm ({} cy)\n  economical: {}",
                        fp.tiles, fp.mem_kb, fp.area_mm2, fp.chip_side_mm,
                        fp.io_area_mm2, fp.switch_area_mm2, fp.wire_area_mm2,
                        fp.wire_tile_mm, fp.cycles.tile, fp.wire_hop_mm, fp.cycles.mesh_hop,
                        fp.is_economical(&tech.chip),
                    );
                }
            }
        }
        "latency" => {
            let dp = design_point(&args, &doc, 1024, None)?;
            let setup = dp.build()?;
            let (tiles, mem, k) = (setup.map.tiles, setup.mem_kb, setup.map.k);
            let exact = setup.expected_latency();
            let seq = SequentialMachine::with_measured_dram(1);
            // One-point sweep through the engine: same path as `sweep`
            // and the figures, so `--jobs 1` vs `--jobs N` is
            // bit-identical by construction.
            let opts = fig_opts(&args, &doc)?;
            let engine = opts.engine();
            let point = SweepPoint { kind: dp.kind(), tiles, mem_kb: mem, k };
            let eval = engine.eval_points(&[point])?[0];
            let name = format!("{}-{tiles}x{mem}-k{k}", kind_str(dp.kind()));
            if args.has("json") {
                let mut report = Report::new("latency");
                report.push(
                    Row::new(&name)
                        .str("backend", eval.backend)
                        .num("mean_cycles", eval.mean_cycles)
                        .int("samples", eval.samples as u64)
                        .num("exact_cycles", exact)
                        .num("vs_ddr3", eval.mean_cycles / seq.dram_ns),
                );
                print!("{}", report.render());
            } else {
                println!(
                    "{:?} {tiles}-tile system, {mem} KB/tile, k={k}: {exact:.2} cycles/access ({:.2}x DDR3 {:.1} ns)",
                    dp.kind(), exact / seq.dram_ns, seq.dram_ns
                );
                if eval.backend != "exact" {
                    println!(
                        "  {} backend: {:.2} cycles/access ({} samples)",
                        eval.backend, eval.mean_cycles, eval.samples
                    );
                }
            }
        }
        "run" => {
            let name = args
                .positional
                .first()
                .ok_or_else(|| usage_error("program name required"))?;
            let prog = crate::cc::corpus::all()
                .into_iter()
                .find(|p| p.name == *name)
                .ok_or_else(|| {
                    let names: Vec<&str> =
                        crate::cc::corpus::all().iter().map(|p| p.name).collect();
                    usage_error(format!(
                        "unknown program `{name}` (available: {})",
                        names.join(", ")
                    ))
                })?;
            let dp = design_point(&args, &doc, 1024, Some(255))?;

            let direct = compile(prog.source, Backend::Direct)?;
            let emulated = compile(prog.source, Backend::Emulated)?;
            // Tier selection: `--tier auto` (the default) takes the
            // fastest tier the host supports — never a panic, never a
            // silent wrong answer; an *explicit* `--tier jit` on an
            // unsupported host is a typed runtime error (exit 1).
            let tier = match (args.has("legacy"), args.flag("tier")) {
                (true, Some(_)) => {
                    return Err(usage_error(
                        "--legacy conflicts with --tier (it is shorthand for --tier legacy)",
                    ))
                }
                (true, None) | (false, Some("legacy")) => RunTier::Legacy,
                (false, Some("fast")) => RunTier::Fast,
                (false, Some("jit")) => {
                    if !jit::available() {
                        return Err(jit::JitUnsupported::host().into());
                    }
                    RunTier::Jit
                }
                (false, None) | (false, Some("auto")) => {
                    if jit::available() {
                        RunTier::Jit
                    } else {
                        RunTier::Fast
                    }
                }
                (false, Some(other)) => {
                    return Err(usage_error(format!(
                        "flag --tier: unknown tier `{other}` (auto | jit | fast | legacy)"
                    )))
                }
            };
            let run_tier = |code: &[Inst], mem: &mut dyn MemorySystem| -> Result<(RunStats, i64)> {
                match tier {
                    RunTier::Legacy => {
                        let mut m = Machine::new(mem, 1 << 16);
                        Ok((m.run(code)?, m.reg(0)))
                    }
                    RunTier::Fast => {
                        let mut mem = mem;
                        let mut m = FastMachine::new(&mut mem, 1 << 16);
                        Ok((m.run(&predecode(code)?)?, m.reg(0)))
                    }
                    RunTier::Jit => {
                        let compiled = jit::compile(&predecode(code)?)?;
                        let mut mem = mem;
                        let mut m = JitMachine::new(&mut mem, 1 << 16);
                        Ok((m.run(&compiled)?, m.reg(0)))
                    }
                }
            };

            let seq = SequentialMachine::with_measured_dram(1);
            let mut dmem = DirectMemory::new(seq, 1 << 24);
            let (dstats, dres): (RunStats, i64) = run_tier(&direct.code, &mut dmem)?;

            let mut emem = EmulatedChannelMemory::new(dp.build()?);
            let (estats, eres): (RunStats, i64) = run_tier(&emulated.code, &mut emem)?;

            println!(
                "program `{}` ({} tier):",
                prog.name,
                match tier {
                    RunTier::Legacy => "legacy enum-match",
                    RunTier::Fast => "pre-decoded fast",
                    RunTier::Jit => "baseline JIT",
                }
            );
            println!(
                "  sequential: result {dres}, {} insts, {} cycles (binary {} B)",
                dstats.instructions, dstats.cycles, direct.binary_bytes()
            );
            println!(
                "  emulated  : result {eres}, {} insts, {} cycles (binary {} B, +{:.1}%)",
                estats.instructions,
                estats.cycles,
                emulated.binary_bytes(),
                100.0 * (emulated.binary_bytes() as f64 / direct.binary_bytes() as f64 - 1.0)
            );
            println!(
                "  slowdown  : {:.2}x",
                estats.cycles as f64 / dstats.cycles as f64
            );
            if dres != eres {
                bail!("machines disagree: {dres} vs {eres}");
            }
        }
        "contention" => {
            let clients_list: Vec<usize> = {
                let raw = args.flag_all("clients");
                if raw.is_empty() {
                    vec![4]
                } else {
                    raw.iter()
                        .map(|s| {
                            s.parse::<usize>()
                                .map_err(|_| usage_error(format!("--clients: cannot parse `{s}`")))
                        })
                        .collect::<Result<_>>()?
                }
            };
            if let Some(&bad) = clients_list.iter().find(|&&c| c == 0) {
                return Err(usage_error(format!(
                    "--clients {bad}: need at least one client per scenario"
                )));
            }
            let accesses: usize = args.get("samples", 500)?;
            if accesses == 0 {
                return Err(usage_error("--samples 0: need at least one access per client"));
            }
            let dp = design_point(&args, &doc, 256, None)?;
            let point = SweepPoint {
                kind: dp.kind(),
                tiles: dp.system_tiles(),
                mem_kb: dp.tile_mem_kb(),
                k: dp.emulation_tiles(),
            };
            // Each (pattern, clients) cell is ONE causally-dependent
            // DES timeline — inherently sequential — so the grid fans
            // out across cells on the sweep engine; any `--jobs` count
            // is bit-identical to the sequential pass (canonical
            // per-cell seeds).
            let mut opts = fig_opts(&args, &doc)?;
            opts.seed = args.get("seed", 5)?;
            let engine = opts.engine();

            let trace_names = args.flag_all("trace");
            let rows: Vec<figures::contention::CellResult> = if trace_names.is_empty() {
                let patterns: Vec<crate::workload::TracePattern> = {
                    let raw = args.flag_all("pattern");
                    let specs =
                        if raw.is_empty() { vec!["uniform".to_string()] } else { raw };
                    specs
                        .iter()
                        .map(|s| {
                            crate::workload::TracePattern::parse(s)
                                .map_err(|e| usage_error(format!("{e:#}")))
                        })
                        .collect::<Result<_>>()?
                };
                let cells: Vec<figures::contention::Cell> = patterns
                    .iter()
                    .flat_map(|&pattern| {
                        clients_list.iter().map(move |&clients| figures::contention::Cell {
                            point,
                            pattern,
                            clients,
                            accesses,
                        })
                    })
                    .collect();
                figures::contention::eval_cells(&engine, &cells)?
            } else {
                // Captured-trace replay: each named corpus program is
                // run once on the FastMachine and its emulated-memory
                // accesses become a client trace (clients cycle through
                // the captured set — heterogeneous when several are
                // named).
                let setup = dp.build()?;
                let captured: Vec<crate::workload::Trace> = trace_names
                    .iter()
                    .map(|name| crate::workload::capture_corpus_program(name, &setup))
                    .collect::<Result<_>>()?;
                let label = format!("trace:{}", trace_names.join("+"));
                let seed = engine.seed();
                engine.map(&clients_list, |&clients| {
                    let cell_seed = crate::coordinator::point_seed(
                        seed,
                        0x7ACE ^ ((clients as u64) << 1) ^ ((accesses as u64) << 24),
                    );
                    Ok(figures::contention::CellResult {
                        point,
                        pattern: label.clone(),
                        clients,
                        stats: run_scenario(
                            &setup,
                            clients,
                            accesses,
                            cell_seed,
                            Workload::Traces(&captured),
                        )?,
                    })
                })?
            };

            if args.has("json") {
                print!("{}", figures::contention::report_rows(&rows).render());
            } else {
                for r in &rows {
                    let s = &r.stats;
                    println!(
                        "{:>14} x{:>3} clients, {accesses} accesses: mean {:.1} cy  p50 {:.1}  p95 {:.1}  p99 {:.1}  max {:.0}  c_cont {:.3}  wait {:.1} cy  port-util max {:.2}",
                        r.pattern,
                        r.clients,
                        s.latency.mean(),
                        s.dist.p50,
                        s.dist.p95,
                        s.dist.p99,
                        s.dist.max,
                        s.c_cont,
                        s.wait.mean(),
                        s.port_util_max,
                    );
                }
            }
        }
        "faults" => {
            // The availability/tail-inflation experiment: replay the
            // trace catalogue under seed-deterministic fault plans of
            // rising severity and report slowdown + p99 inflation
            // against the healthy (fraction 0) baseline of the same
            // grid. Every cell is one DES timeline fanned out over
            // --jobs; any job count is bit-identical.
            let opts = fig_opts(&args, &doc)?;
            let engine = opts.engine();
            let rows = figures::faults::generate_with(&engine)?;
            if args.has("json") {
                print!("{}", figures::faults::report(&rows).render());
            } else {
                print!("{}", figures::faults::render(&rows));
            }
        }
        "fuzz" => fuzz_cmd(&args)?,
        "snapshot" => snapshot_cmd(&args)?,
        "serve" => serve_cmd(&args, &doc, &tech)?,
        "loadgen" => loadgen_cmd(&args, &doc, &tech)?,
        "selfcheck" => selfcheck(&args, &tech)?,
        "bench-hotpath" => {
            let setup = figures::hotpath::design_point()?;
            let b = figures::hotpath::measure(&setup);
            print!("{}", figures::hotpath::render(&setup, &b));
            let out = args.flag("out").unwrap_or("BENCH_hotpath.json");
            b.write_json(std::path::Path::new(out))
                .with_context(|| format!("writing {out}"))?;
            println!("wrote {out}");
            figures::hotpath::assert_hotpath(&b)?;
            println!(
                "throughput assertions OK (LUT {:.1}x routed)",
                figures::hotpath::lut_speedup(&b)?
            );
        }
        "bench-interp" => {
            let w = figures::interp_bench::workload()?;
            let b = figures::interp_bench::measure(&w);
            print!("{}", figures::interp_bench::render(&b));
            let out = args.flag("out").unwrap_or("BENCH_interp.json");
            b.write_json(std::path::Path::new(out))
                .with_context(|| format!("writing {out}"))?;
            println!("wrote {out}");
            figures::interp_bench::assert_interp(&b)?;
            println!(
                "interp assertions OK (decoded {:.1}x legacy on the emulated corpus)",
                figures::interp_bench::speedup(&b)?
            );
        }
        "bench-jit" => {
            let out = args.flag("out").unwrap_or("BENCH_jit.json");
            if !jit::available() {
                // Degrade explicitly: record an empty jit group so the
                // BENCH artifact family stays complete on every host,
                // and say why the floor was not enforced.
                crate::util::bench::Bench::new("jit")
                    .write_json(std::path::Path::new(out))
                    .with_context(|| format!("writing {out}"))?;
                println!("wrote {out} (empty result set)");
                println!("skipping jit floor: {}", jit::JitUnsupported::host());
            } else {
                let w = figures::interp_bench::workload()?;
                let b = figures::interp_bench::measure_jit(&w)?;
                print!("{}", figures::interp_bench::render_jit(&b));
                b.write_json(std::path::Path::new(out))
                    .with_context(|| format!("writing {out}"))?;
                println!("wrote {out}");
                figures::interp_bench::assert_jit(&b)?;
                println!(
                    "jit assertions OK (jit {:.1}x legacy on the emulated corpus)",
                    figures::interp_bench::jit_speedup(&b)?
                );
            }
        }
        "sweep" => {
            let dp = design_point(&args, &doc, 1024, None)?;
            let (kind, tiles) = (dp.kind(), dp.system_tiles());
            let mem = dp.tile_mem_kb();
            let mut points = Vec::new();
            let mut k = 16usize;
            while k < tiles {
                points.push(SweepPoint { kind, tiles, mem_kb: mem, k });
                k *= 2;
            }
            points.push(SweepPoint { kind, tiles, mem_kb: mem, k: tiles - 1 });
            let opts = fig_opts(&args, &doc)?;
            let engine = opts.engine();
            let mut results = engine.eval_points(&points)?;
            results.sort_by_key(|r| r.point.k);
            if args.has("json") {
                let mut report = Report::new("sweep");
                for r in &results {
                    report.push(
                        Row::new(&format!("{}-{tiles}-k{}", kind_str(kind), r.point.k))
                            .int("k", r.point.k as u64)
                            .str("backend", r.backend)
                            .num("mean_cycles", r.mean_cycles)
                            .int("samples", r.samples as u64),
                    );
                }
                print!("{}", report.render());
            } else {
                println!("k tiles  latency (cycles)");
                for r in &results {
                    println!("{:>7}  {:.2}", r.point.k, r.mean_cycles);
                }
            }
        }
        other => return Err(usage_error(format!("unknown command `{other}` (try --help)"))),
    }
    Ok(())
}

/// `memclos fuzz`: the generative differential fuzzer (or a one-shot
/// artifact replay). Divergences are runtime failures (exit 1); flag
/// misuse is exit 2.
fn fuzz_cmd(args: &Args) -> Result<()> {
    if args.has("shrink") && args.has("no-shrink") {
        return Err(usage_error("--shrink conflicts with --no-shrink"));
    }
    if let Some(path) = args.flag("replay") {
        if args.flag("cases").is_some() {
            return Err(usage_error(
                "--replay re-runs one artifact; it conflicts with --cases",
            ));
        }
        let path = std::path::Path::new(path);
        return match crate::workload::fuzzgen::replay_file(path)? {
            None => {
                println!("replay {}: no divergence", path.display());
                Ok(())
            }
            Some(d) => bail!("replay {}: divergence reproduces: {d}", path.display()),
        };
    }
    let cases: u64 = args.get("cases", 1000u64)?;
    if cases == 0 {
        return Err(usage_error("--cases 0: need at least one case"));
    }
    let max_failures: usize = args.get("max-failures", 5usize)?;
    if max_failures == 0 {
        return Err(usage_error("--max-failures 0: need room for at least one failure"));
    }
    let cfg = crate::workload::FuzzConfig {
        seed: args.get("seed", 0u64)?,
        cases,
        shrink: !args.has("no-shrink"),
        out_dir: Some(std::path::PathBuf::from(args.flag("out").unwrap_or("."))),
        max_failures,
    };
    let summary = crate::workload::run_fuzz(&cfg)?;
    println!(
        "fuzz: {} cases (seed {}), {} snapshot-slice checks, {} divergences",
        summary.cases,
        cfg.seed,
        summary.snapshot_checks,
        summary.failures.len()
    );
    for f in &summary.failures {
        println!("  case {}: {}", f.index, f.divergence);
        if let Some(p) = &f.artifact {
            println!("    artifact: {}", p.display());
        }
    }
    if !summary.failures.is_empty() {
        bail!("{} of {} cases diverged", summary.failures.len(), summary.cases);
    }
    Ok(())
}

/// `memclos snapshot {save,resume}`.
fn snapshot_cmd(args: &Args) -> Result<()> {
    let sub = args
        .positional
        .first()
        .ok_or_else(|| usage_error("snapshot needs a subcommand: save | resume"))?;
    match sub.as_str() {
        "save" => snapshot_save(args),
        "resume" => snapshot_resume(args),
        other => {
            Err(usage_error(format!("unknown snapshot subcommand `{other}` (save | resume)")))
        }
    }
}

/// Build the memory a snapshot run executes over, from the command
/// line (the emulated point must be a `default_tech` design so resume
/// can rebuild and verify it from the recorded identity alone).
fn snapshot_memory(args: &Args) -> Result<RebuiltMemory> {
    match args.flag("backend").unwrap_or("emulated") {
        "direct" => Ok(RebuiltMemory::Direct(DirectMemory::new(
            SequentialMachine::paper_figures(false),
            1 << 24,
        ))),
        "emulated" => {
            let kind = TopologyKind::parse(args.flag("topo").unwrap_or("clos"))
                .map_err(|e| usage_error(format!("{e:#}")))?;
            let setup = EmulationSetup::default_tech(
                kind,
                args.get("tiles", 256usize)?,
                args.get("mem", 64u32)?,
                args.get("k", 128usize)?,
            )?;
            Ok(RebuiltMemory::Emulated(EmulatedChannelMemory::new(setup)))
        }
        other => Err(usage_error(format!(
            "--backend must be `direct` or `emulated`, not `{other}`"
        ))),
    }
}

/// `memclos snapshot save`: run a corpus program to a cycle budget and
/// freeze the complete machine state.
fn snapshot_save(args: &Args) -> Result<()> {
    let name = args
        .flag("program")
        .ok_or_else(|| usage_error("snapshot save needs --program NAME"))?
        .to_string();
    let at: u64 = args.get("at", 0u64)?;
    if at == 0 {
        return Err(usage_error("snapshot save needs --at CYCLES (a positive pause budget)"));
    }
    let prog = crate::cc::corpus::all()
        .into_iter()
        .find(|p| p.name == name)
        .ok_or_else(|| {
            let names: Vec<&str> = crate::cc::corpus::all().iter().map(|p| p.name).collect();
            usage_error(format!("unknown program `{name}` (available: {})", names.join(", ")))
        })?;
    let mut memory = snapshot_memory(args)?;
    let cc_backend = match &memory {
        RebuiltMemory::Direct(_) => Backend::Direct,
        RebuiltMemory::Emulated(_) => Backend::Emulated,
    };
    let compiled = compile(prog.source, cc_backend)?;
    let legacy = args.has("legacy");
    let local_words = 1 << 16;

    let mut cursor = ExecCursor::default();
    let (state, max_steps, outcome) = if legacy {
        let mut m = Machine::new(memory.as_dyn(), local_words);
        let outcome = m.run_until(&compiled.code, &mut cursor, Some(at))?;
        (m.export_state(&cursor), m.max_steps, outcome)
    } else {
        let decoded = predecode(&compiled.code)?;
        let mut mem = memory.as_dyn();
        let mut m = FastMachine::new(&mut mem, local_words);
        let outcome = m.run_until(&decoded, &mut cursor, Some(at))?;
        (m.export_state(&cursor), m.max_steps, outcome)
    };
    if matches!(outcome, RunOutcome::Halted) {
        bail!(
            "program `{name}` halted after {} cycles, before the --at {at} pause point",
            cursor.stats.cycles
        );
    }

    let (backend, pages, space_words) = match &memory {
        RebuiltMemory::Direct(m) => {
            (BackendSnap::of_direct(m), Snapshot::pages_of(m.store()), m.space_words())
        }
        RebuiltMemory::Emulated(m) => {
            (BackendSnap::of_emulated(m), Snapshot::pages_of(m.store()), m.space_words())
        }
    };
    let snap = Snapshot {
        tier: if legacy { Tier::Legacy } else { Tier::Fast },
        backend,
        space_words,
        max_steps,
        program: name.clone(),
        program_fnv: program_fingerprint(&compiled.code),
        state,
        pages,
    };
    let out = args.flag("out").map(|s| s.to_string()).unwrap_or_else(|| format!("{name}.snap"));
    std::fs::write(&out, snap.to_bytes()).with_context(|| format!("writing {out}"))?;
    println!(
        "wrote {out}: `{name}` on the {} backend, {} tier, paused at {} cycles ({} instructions, {} pages)",
        snap.backend.label(),
        snap.tier.label(),
        cursor.stats.cycles,
        cursor.stats.instructions,
        snap.pages.len()
    );
    Ok(())
}

/// `memclos snapshot resume`: rebuild a snapshot's memory and machine
/// and run to completion; `--verify` additionally reruns from cycle 0
/// and asserts the two runs are bit-identical.
fn snapshot_resume(args: &Args) -> Result<()> {
    let path = args.flag("in").ok_or_else(|| usage_error("snapshot resume needs --in FILE"))?;
    let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
    let snap = Snapshot::from_bytes(&bytes).with_context(|| format!("loading snapshot {path}"))?;
    let prog = crate::cc::corpus::all()
        .into_iter()
        .find(|p| p.name == snap.program)
        .ok_or_else(|| {
            anyhow::anyhow!("snapshot program `{}` is not in the corpus", snap.program)
        })?;
    let cc_backend = match &snap.backend {
        BackendSnap::Direct { .. } => Backend::Direct,
        BackendSnap::Emulated { .. } => Backend::Emulated,
    };
    let compiled = compile(prog.source, cc_backend)?;
    snap.check_program(&compiled.code)?;
    let decoded = match snap.tier {
        Tier::Fast | Tier::Jit => Some(predecode(&compiled.code)?),
        Tier::Legacy => None,
    };
    // A jit-tier snapshot resumes under the JIT where the host supports
    // it; elsewhere it degrades — explicitly, with a notice — to the
    // fast tier, which shares the decoded cursor space bit-identically.
    let jit_prog = match snap.tier {
        Tier::Jit if jit::available() => {
            Some(jit::compile(decoded.as_ref().expect("jit tier predecodes"))?)
        }
        Tier::Jit => {
            eprintln!(
                "note: resuming a jit-tier snapshot on the fast tier ({})",
                jit::JitUnsupported::host()
            );
            None
        }
        _ => None,
    };
    let run_from = |state: &MachineState, memory: &mut RebuiltMemory| match (&jit_prog, &decoded) {
        (Some(jp), _) => run_jit_slice(jp, memory.as_dyn(), state, snap.max_steps, None),
        (None, Some(d)) => run_fast_slice(d, memory.as_dyn(), state, snap.max_steps, None),
        (None, None) => {
            run_legacy_slice(&compiled.code, memory.as_dyn(), state, snap.max_steps, None)
        }
    };

    let mut memory = rebuild_memory(&snap)?;
    let resumed = run_from(&snap.state, &mut memory);
    match &resumed.outcome {
        Ok(true) => {}
        Ok(false) => bail!("unbounded resume paused"),
        Err(e) => bail!("resumed run failed: {e}"),
    }
    println!(
        "resumed `{}` from {path} ({} tier, {} backend): halted at {} cycles, {} instructions, r0 = {}",
        snap.program,
        snap.tier.label(),
        snap.backend.label(),
        resumed.state.stats.cycles,
        resumed.state.stats.instructions,
        resumed.state.regs[0]
    );
    if args.has("verify") {
        // An uninterrupted run of the same program on a blank memory of
        // the same design, with the same local-memory size.
        let blank = Snapshot { state: MachineState::default(), pages: Vec::new(), ..snap.clone() };
        let mut fresh = rebuild_memory(&blank)?;
        let start = MachineState {
            local: vec![0; snap.state.local.len()],
            ..MachineState::default()
        };
        let reference = run_from(&start, &mut fresh);
        let ok = matches!(reference.outcome, Ok(true))
            && reference.state.stats == resumed.state.stats
            && reference.state.regs == resumed.state.regs;
        if ok {
            println!(
                "verify OK: resumed run is bit-identical to an uninterrupted run ({} cycles)",
                resumed.state.stats.cycles
            );
        } else {
            bail!(
                "verify FAILED: resumed {:?} r0={} vs uninterrupted {:?} r0={}",
                resumed.state.stats,
                resumed.state.regs[0],
                reference.state.stats,
                reference.state.regs[0]
            );
        }
    }
    Ok(())
}

/// The service+server config shared by `serve` and `loadgen
/// --self-host`.
fn serve_config(args: &Args, doc: &Doc, tech: &Tech) -> Result<(ServeConfig, ServerConfig)> {
    let defaults = ServeConfig::default();
    let scfg = ServeConfig {
        mode: eval_mode(args)?,
        tech: tech.clone(),
        jobs: fig_opts(args, doc)?.jobs,
        cache_entries: args.get("cache-entries", defaults.cache_entries)?,
        cache_bytes: args.get("cache-bytes", defaults.cache_bytes)?,
        linger: Duration::from_micros(args.get("linger-us", 1_000u64)?),
        batch_max: args.get("batch-max", defaults.batch_max)?,
        max_engines: args.get("max-engines", defaults.max_engines)?,
    };
    let net = ServerConfig::default();
    let srv = ServerConfig {
        addr: args.flag("addr").unwrap_or("127.0.0.1:7077").to_string(),
        net_workers: args.get("net-workers", net.net_workers)?,
        queue_depth: args.get("queue-depth", net.queue_depth)?,
        session_inflight: args.get("session-inflight", net.session_inflight)?,
    };
    Ok((scfg, srv))
}

/// `memclos serve`: run the service until a `shutdown` request or
/// SIGINT, then drain and report.
fn serve_cmd(args: &Args, doc: &Doc, tech: &Tech) -> Result<()> {
    let (scfg, srv_cfg) = serve_config(args, doc, tech)?;
    let server = Server::start(Arc::new(Service::new(scfg)), &srv_cfg)?;
    let addr = server.local_addr();
    println!("memclos serve listening on {addr}");
    if let Some(path) = args.flag("port-file") {
        std::fs::write(path, format!("{}\n", addr.port()))
            .with_context(|| format!("writing {path}"))?;
    }
    install_sigint();
    while !server.is_draining() && !sigint_seen() {
        std::thread::sleep(Duration::from_millis(100));
    }
    if sigint_seen() {
        eprintln!("SIGINT: draining");
        server.request_shutdown();
    }
    let report = server.join();
    println!("drained: {report}");
    Ok(())
}

/// `memclos loadgen`: drive a server (external via --addr, or an
/// in-process one via --self-host) and write `BENCH_serve.json`.
fn loadgen_cmd(args: &Args, doc: &Doc, tech: &Tech) -> Result<()> {
    let self_host = args.has("self-host");
    let defaults = LoadgenOpts::default();
    let mut opts = LoadgenOpts {
        addr: match args.flag("addr") {
            Some(a) => a.to_string(),
            None if self_host => String::new(),
            None => {
                return Err(usage_error("loadgen needs --addr HOST:PORT (or --self-host)"))
            }
        },
        clients: args.get("clients", defaults.clients)?,
        requests: args.get("requests", defaults.requests)?,
        seed: args.get("seed", defaults.seed)?,
        // Self-hosting must drain, or the in-process server would
        // outlive the run.
        shutdown: args.has("shutdown") || self_host,
    };
    if opts.clients == 0 {
        return Err(usage_error("--clients 0: need at least one client"));
    }
    if opts.requests == 0 {
        return Err(usage_error("--requests 0: need at least one request per client"));
    }
    let server = if self_host {
        let (scfg, _) = serve_config(args, doc, tech)?;
        let srv_cfg = ServerConfig { addr: "127.0.0.1:0".to_string(), ..ServerConfig::default() };
        let server = Server::start(Arc::new(Service::new(scfg)), &srv_cfg)?;
        opts.addr = server.local_addr().to_string();
        eprintln!("self-hosted serve on {}", opts.addr);
        Some(server)
    } else {
        None
    };

    let summary = crate::serve::loadgen::run(&opts)?;
    print!("{}", summary.render());
    if args.has("json") {
        print!("{}", summary.report().render());
    }
    if let Some(out) = args.flag("out") {
        summary.report().write(std::path::Path::new(out))
            .with_context(|| format!("writing {out}"))?;
        println!("wrote {out}");
    }
    if let Some(server) = server {
        // The wire shutdown has already flipped the drain flag; join
        // retires the acceptor, connections and workers.
        let report = server.join();
        println!("drained: {report}");
    }
    if summary.errors > 0 {
        bail!("{} of {} requests failed", summary.errors, summary.sent);
    }
    if opts.shutdown && summary.drain_clean != Some(true) {
        bail!("server drain was not clean");
    }
    Ok(())
}

/// Prove the evaluation paths agree: exact expectation, native
/// Monte-Carlo batches, and the AOT XLA kernel, via the api backends.
fn selfcheck(args: &Args, tech: &Tech) -> Result<()> {
    let set = crate::runtime::ArtifactSet::new()?;
    println!("PJRT platform: {}", set.platform());
    if !set.available("latency_batch_4096") {
        bail!("artifacts missing — run `make artifacts` first");
    }
    let backend = XlaBackend::load_from(&set, 4096)?;
    let mut rng = crate::util::rng::Rng::new(args.get("seed", 0xABCD)?);
    let mut worst = 0f32;
    let mut checked = 0usize;
    for kind in [TopologyKind::Clos, TopologyKind::Mesh] {
        for &(tiles, mem) in &[(256usize, 64u32), (1024, 128), (4096, 128)] {
            for &k in &[15usize, 255, 1023] {
                if k >= tiles {
                    continue;
                }
                let setup = DesignPoint::new(kind, tiles)
                    .mem_kb(mem)
                    .k(k)
                    .tech(tech)
                    .build()?;
                let mut addrs = vec![0i32; 4096];
                rng.fill_addresses(setup.map.space_words(), &mut addrs);
                let (xla_lat, _) = backend.batch_latencies(&setup, &addrs)?;
                let mut native = Vec::new();
                setup.native_batch(&addrs, &mut native);
                for i in 0..addrs.len() {
                    let diff = (xla_lat[i] - native[i]).abs();
                    worst = worst.max(diff);
                    if diff > 1e-4 {
                        bail!(
                            "MISMATCH {kind:?} tiles={tiles} mem={mem} k={k} addr={}: xla {} native {}",
                            addrs[i],
                            xla_lat[i],
                            native[i]
                        );
                    }
                }
                checked += addrs.len();
            }
        }
    }
    println!("selfcheck OK: {checked} accesses across 16 design points, worst |xla-native| = {worst}");
    Ok(())
}
