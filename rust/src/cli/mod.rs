//! Hand-rolled CLI argument parsing (clap is unavailable offline) and
//! the typed-error contract of the binary.
//!
//! Grammar: `memclos <command> [positional...] [--flag [value]]...`.
//! Flags may repeat (`--set a=1 --set b=2`). `--help` is handled by the
//! binary ([`driver`]).
//!
//! Every misuse of the command line — unknown command, malformed flag
//! value, unreadable `--config` — is a typed [`UsageError`] mapped to
//! **exit code 2** by [`exit_code`]; runtime failures (evaluation
//! errors, I/O mid-run) keep exit code 1. Nothing panics on bad input.

use std::collections::HashMap;

use anyhow::Result;

pub mod driver;

/// Typed command-line misuse: something the *caller* got wrong (unknown
/// command or figure, unparseable flag value, missing argument,
/// unreadable `--config`). The binary maps it to exit code 2 so scripts
/// can tell misuse from runtime failure.
#[derive(Clone, Debug, PartialEq, Eq, thiserror::Error)]
#[error("{0}")]
pub struct UsageError(pub String);

/// Build a [`UsageError`] wrapped as an [`anyhow::Error`].
pub fn usage_error(msg: impl Into<String>) -> anyhow::Error {
    anyhow::Error::new(UsageError(msg.into()))
}

/// The process exit code for a failed run: 2 for command-line misuse
/// (a [`UsageError`] anywhere in the chain), 1 for runtime failure.
pub fn exit_code(err: &anyhow::Error) -> i32 {
    if err.chain().any(|c| c.downcast_ref::<UsageError>().is_some()) {
        2
    } else {
        1
    }
}

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// The subcommand (first non-flag argument).
    pub command: String,
    /// Positional arguments after the command.
    pub positional: Vec<String>,
    /// Flag values; flags without a value get "true".
    flags: HashMap<String, Vec<String>>,
}

/// Flags that take no value.
const BOOLEAN_FLAGS: &[&str] = &[
    "help", "quick", "tsv", "no-plot", "verbose", "json", "legacy", "all", "shutdown",
    "self-host", "shrink", "no-shrink", "verify",
];

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    return Err(usage_error("bare `--` is not supported"));
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if BOOLEAN_FLAGS.contains(&name) {
                    out.flags.entry(name.to_string()).or_default().push("true".into());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| usage_error(format!("flag --{name} expects a value")))?;
                    out.flags.entry(name.to_string()).or_default().push(v);
                }
            } else if out.command.is_empty() {
                out.command = arg;
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Last value of a flag.
    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All values of a repeatable flag.
    pub fn flag_all(&self, name: &str) -> Vec<String> {
        self.flags.get(name).cloned().unwrap_or_default()
    }

    /// Boolean flag presence.
    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    /// Typed flag with default.
    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| usage_error(format!("flag --{name}: cannot parse `{v}`"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn json_is_boolean() {
        let a = parse("latency --json --tiles 1024");
        assert!(a.has("json"));
        assert_eq!(a.get::<usize>("tiles", 0).unwrap(), 1024);
    }

    #[test]
    fn legacy_is_boolean() {
        let a = parse("run sieve --legacy --topo clos");
        assert!(a.has("legacy"));
        assert_eq!(a.flag("topo"), Some("clos"));
    }

    #[test]
    fn command_and_flags() {
        let a = parse("figure 9 --topo clos --samples 100000 --tsv");
        assert_eq!(a.command, "figure");
        assert_eq!(a.positional, vec!["9"]);
        assert_eq!(a.flag("topo"), Some("clos"));
        assert_eq!(a.get::<usize>("samples", 0).unwrap(), 100000);
        assert!(a.has("tsv"));
    }

    #[test]
    fn repeated_set_flags() {
        let a = parse("latency --set a=1 --set net.t_open=0");
        assert_eq!(a.flag_all("set"), vec!["a=1", "net.t_open=0"]);
    }

    #[test]
    fn equals_syntax() {
        let a = parse("area --tiles=256");
        assert_eq!(a.flag("tiles"), Some("256"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(["x".into(), "--topo".into()]).is_err());
    }

    #[test]
    fn typed_default() {
        let a = parse("dram");
        assert_eq!(a.get::<usize>("ranks", 1).unwrap(), 1);
    }

    #[test]
    fn misuse_is_a_usage_error_with_exit_code_2() {
        let err = Args::parse(["x".into(), "--topo".into()]).unwrap_err();
        assert!(err.downcast_ref::<UsageError>().is_some());
        assert_eq!(exit_code(&err), 2);
        let err = parse("latency --tiles abc").get::<usize>("tiles", 0).unwrap_err();
        assert_eq!(err.to_string(), "flag --tiles: cannot parse `abc`");
        assert_eq!(exit_code(&err), 2);
        // Runtime failures keep exit code 1 — even wrapped in context.
        let runtime = anyhow::anyhow!("backend exploded").context("evaluating point");
        assert_eq!(exit_code(&runtime), 1);
        // ...and a UsageError keeps code 2 through added context.
        let wrapped = usage_error("bad flag").context("parsing command line");
        assert_eq!(exit_code(&wrapped), 2);
    }
}
