//! Network performance-model parameters (paper §6.3, Table 5) and the
//! parameter vectors shared with the AOT kernel.

use crate::config::Doc;

/// Table 5: switch-level latency parameters, in cycles (fitted to
/// XMP-64 measurements by the paper).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetParams {
    /// Switch traversal latency.
    pub t_switch: f64,
    /// Additional latency to open a route through a switch.
    pub t_open: f64,
    /// Switch contention factor (1.0 at zero load).
    pub c_cont: f64,
    /// Serialisation latency, intra-chip messages.
    pub t_serial_intra: f64,
    /// Serialisation latency, inter-chip messages (half-width links).
    pub t_serial_inter: f64,
    /// Tile memory (SRAM) access latency in cycles.
    pub t_mem: f64,
    /// If true, routes are held open between accesses (t_open elided).
    pub route_open: bool,
}

impl Default for NetParams {
    fn default() -> Self {
        Self {
            t_switch: 2.0,
            t_open: 5.0,
            c_cont: 1.0,
            t_serial_intra: 0.0,
            t_serial_inter: 2.0,
            t_mem: 1.0, // 0.5 ns SRAM at 1 GHz, rounded up to a cycle
            route_open: false,
        }
    }
}

impl NetParams {
    /// Build from a config doc (keys under `net.`), defaulting to
    /// Table 5.
    pub fn from_doc(doc: &Doc) -> Self {
        let d = Self::default();
        Self {
            t_switch: doc.float("net.t_switch", d.t_switch),
            t_open: doc.float("net.t_open", d.t_open),
            c_cont: doc.float("net.c_cont", d.c_cont),
            t_serial_intra: doc.float("net.t_serial_intra", d.t_serial_intra),
            t_serial_inter: doc.float("net.t_serial_inter", d.t_serial_inter),
            t_mem: doc.float("net.t_mem", d.t_mem),
            route_open: doc.bool("net.route_open", d.route_open),
        }
    }

    /// Per-switch latency including route opening (the `t_open +
    /// t_switch * c_cont` term of the §6.3 model).
    pub fn per_switch(&self) -> f64 {
        let open = if self.route_open { 0.0 } else { self.t_open };
        open + self.t_switch * self.c_cont
    }
}

/// Encoded parameters for one latency-kernel invocation (contract v1 —
/// see `runtime::engine` for the slot layout, which is mirrored by
/// `python/compile/kernels/latency.py`).
#[derive(Clone, Debug, PartialEq)]
pub struct KernelParams {
    /// Integer parameters (topology discriminator, shifts, counts).
    pub iparams: [i32; 16],
    /// Float parameters (per-stage latencies in cycles).
    pub fparams: [f32; 16],
}

impl KernelParams {
    /// iparams: topology discriminator (0 = Clos, 1 = mesh).
    pub const IP_TOPO: usize = 0;
    /// iparams: log2 words per tile.
    pub const IP_LOG2_WPT: usize = 1;
    /// iparams: memory tiles in the emulation.
    pub const IP_K: usize = 2;
    /// iparams: Clos log2 tiles per edge switch.
    pub const IP_LOG2_G0: usize = 3;
    /// iparams: Clos log2 tiles per chip.
    pub const IP_LOG2_G1: usize = 4;
    /// iparams: mesh log2 tiles per block.
    pub const IP_LOG2_BLOCK: usize = 5;
    /// iparams: mesh system blocks per row.
    pub const IP_BLOCKS_X: usize = 6;
    /// iparams: mesh blocks per row per chip.
    pub const IP_CHIP_BLOCKS_X: usize = 7;
    /// iparams: routes pre-opened flag.
    pub const IP_ROUTE_OPEN: usize = 8;
    /// iparams: client tile index.
    pub const IP_CLIENT: usize = 9;
    /// iparams: total system tiles.
    pub const IP_TILES: usize = 10;

    /// fparams: tile<->switch link latency.
    pub const FP_T_TILE: usize = 0;
    /// fparams: switch traversal.
    pub const FP_T_SWITCH: usize = 1;
    /// fparams: route-opening latency.
    pub const FP_T_OPEN: usize = 2;
    /// fparams: contention factor.
    pub const FP_C_CONT: usize = 3;
    /// fparams: intra-chip serialisation.
    pub const FP_SER_INTRA: usize = 4;
    /// fparams: inter-chip serialisation.
    pub const FP_SER_INTER: usize = 5;
    /// fparams: tile memory access.
    pub const FP_T_MEM: usize = 6;
    /// fparams: Clos edge<->core link.
    pub const FP_LINK_EDGE_CORE: usize = 7;
    /// fparams: Clos core<->system-core link.
    pub const FP_LINK_CORE_SYS: usize = 8;
    /// fparams: mesh per-hop link.
    pub const FP_MESH_LINK: usize = 9;
    /// fparams: mesh per-chip-crossing extra.
    pub const FP_MESH_CROSS_EXTRA: usize = 10;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table5() {
        let p = NetParams::default();
        assert_eq!(p.t_switch, 2.0);
        assert_eq!(p.t_open, 5.0);
        assert_eq!(p.t_serial_inter, 2.0);
        assert_eq!(p.per_switch(), 7.0);
    }

    #[test]
    fn route_open_elides_topen() {
        let p = NetParams { route_open: true, ..Default::default() };
        assert_eq!(p.per_switch(), 2.0);
    }

    #[test]
    fn config_override() {
        let doc = Doc::parse("[net]\nt_switch = 3.0\nroute_open = true").unwrap();
        let p = NetParams::from_doc(&doc);
        assert_eq!(p.t_switch, 3.0);
        assert!(p.route_open);
    }
}
