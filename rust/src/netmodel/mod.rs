//! Analytic network performance model (paper §6.3, Table 5).
//!
//! [`LatencyModel`] evaluates the paper's `t_closed`/`t_open` message
//! latency over routes from [`crate::topology`], with per-link-class
//! latencies ([`LinkLatencies`]) derived from the VLSI floorplans.
//! [`KernelParams`] is the encoding of one design point for the
//! AOT-compiled kernel (contract v1).

mod latency;
mod params;

pub use latency::{LatencyModel, LinkLatencies};
pub use params::{KernelParams, NetParams};
