//! The analytic message-latency model (paper §6.3).
//!
//! ```text
//! t_closed(s,t) = 2*t_tile + t_serial
//!                 + (d(s,t)+1) * (t_open + t_switch*c_cont)
//!                 + sum over links l in p(s,t) of t_link(l)
//! ```
//!
//! (with `t_open` elided when the route is already open). A memory
//! access is a request/response round trip plus the remote tile's SRAM
//! access: `2 * t_closed + t_mem`.
//!
//! Per-link latencies come from the VLSI floorplan ([`LinkLatencies`]);
//! the model is evaluated either natively (here) or by the AOT-compiled
//! kernel ([`crate::runtime::LatencyEngine`]) — a test proves both agree
//! bit-for-bit.

use super::params::NetParams;
use crate::topology::{Route, Topology};

/// Per-link-class latencies in cycles, derived from the floorplan and
/// interposer models.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkLatencies {
    /// Tile <-> switch link.
    pub tile: f64,
    /// Clos edge <-> chip-core link (on-chip).
    pub edge_core: f64,
    /// Clos chip-core <-> system-core link (chip pad run + interposer
    /// channel + remote pad run).
    pub core_sys: f64,
    /// Mesh hop (on-chip).
    pub mesh_hop: f64,
    /// Extra cycles when a mesh hop crosses chips.
    pub mesh_cross_extra: f64,
}

impl LinkLatencies {
    /// Single-cycle links everywhere (the XMP-64-like abstract machine).
    pub fn unit() -> Self {
        Self { tile: 1.0, edge_core: 1.0, core_sys: 1.0, mesh_hop: 1.0, mesh_cross_extra: 0.0 }
    }
}

/// The analytic latency model for one emulation design point.
#[derive(Clone, Debug)]
pub struct LatencyModel {
    /// Network parameters (Table 5).
    pub net: NetParams,
    /// Per-link-class latencies (floorplan-derived).
    pub links: LinkLatencies,
}

impl LatencyModel {
    /// Construct from parameters.
    pub fn new(net: NetParams, links: LinkLatencies) -> Self {
        Self { net, links }
    }

    /// Total link latency along a route.
    pub fn link_sum(&self, r: &Route) -> f64 {
        r.edge_core_links as f64 * self.links.edge_core
            + r.core_sys_links as f64 * self.links.core_sys
            + r.mesh_hops as f64 * self.links.mesh_hop
            + r.chip_crossings as f64 * (self.links.mesh_hop + self.links.mesh_cross_extra)
    }

    /// One-way message latency over a route (t_closed / t_open of §6.3).
    pub fn one_way(&self, r: &Route) -> f64 {
        let ser = if r.inter_chip { self.net.t_serial_inter } else { self.net.t_serial_intra };
        2.0 * self.links.tile + ser + r.switches() as f64 * self.net.per_switch() + self.link_sum(r)
    }

    /// Round-trip memory access latency: request + SRAM + response.
    pub fn round_trip(&self, r: &Route) -> f64 {
        2.0 * self.one_way(r) + self.net.t_mem
    }

    /// Round trip between two tiles of a topology.
    pub fn access(&self, topo: &Topology, client: usize, tile: usize) -> f64 {
        self.round_trip(&topo.route(client, tile))
    }

    /// Materialise the per-rank access-latency LUT for a client: one
    /// `access` evaluation per rank tile, in rank order. This is the
    /// only place routes are computed on the emulation access path —
    /// everything downstream indexes the returned table.
    pub fn access_lut(
        &self,
        topo: &Topology,
        client: usize,
        rank_tiles: impl Iterator<Item = usize>,
    ) -> Vec<f64> {
        rank_tiles.map(|tile| self.access(topo, client, tile)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{ClosSpec, FoldedClos, Mesh2D, MeshSpec};

    fn model() -> LatencyModel {
        let links = LinkLatencies {
            tile: 1.0,
            edge_core: 2.0,
            core_sys: 8.0,
            mesh_hop: 1.0,
            mesh_cross_extra: 1.0,
        };
        LatencyModel::new(NetParams::default(), links)
    }

    #[test]
    fn clos_same_edge_is_19_cycles() {
        let topo = Topology::Clos(FoldedClos::build(ClosSpec::with_tiles(1024)).unwrap());
        let m = model();
        // d=0: one way = 2*1 + 0 + 1*7 = 9; round trip = 19.
        assert_eq!(m.access(&topo, 0, 5), 19.0);
    }

    #[test]
    fn clos_same_chip_is_55_cycles() {
        let topo = Topology::Clos(FoldedClos::build(ClosSpec::with_tiles(1024)).unwrap());
        // d=2: one way = 2 + 0 + 3*7 + 2*2 = 27; rt = 55.
        assert_eq!(model().access(&topo, 0, 17), 55.0);
    }

    #[test]
    fn clos_inter_chip_is_119_cycles() {
        let topo = Topology::Clos(FoldedClos::build(ClosSpec::with_tiles(1024)).unwrap());
        // d=4: one way = 2 + 2 + 5*7 + (2*2+2*8) = 59; rt = 119.
        assert_eq!(model().access(&topo, 0, 300), 119.0);
    }

    #[test]
    fn mesh_hop_gradient() {
        let topo = Topology::Mesh(Mesh2D::build(MeshSpec::with_tiles(1024)).unwrap());
        let m = model();
        let same_block = m.access(&topo, 0, 5);
        let one_hop = m.access(&topo, 0, 16); // block (1,0)
        let two_hops = m.access(&topo, 0, 2 * 16);
        assert_eq!(same_block, 19.0);
        // +1 switch (7) + 1 hop link (1) each way => +16
        assert_eq!(one_hop, 35.0);
        assert_eq!(two_hops, 51.0);
    }

    #[test]
    fn mesh_crossing_pays_serialisation_and_extra() {
        let topo = Topology::Mesh(Mesh2D::build(MeshSpec::with_tiles(1024)).unwrap());
        let m = model();
        let inside = m.access(&topo, 0, 3 * 16); // block (3,0): 3 hops
        let across = m.access(&topo, 0, 4 * 16); // block (4,0): crosses chips
        // +1 switch+link (8) + crossing extra (1) + ser 2, each way
        assert_eq!(across - inside, 2.0 * (8.0 + 1.0 + 2.0));
    }

    #[test]
    fn access_lut_matches_per_rank_access() {
        let topo = Topology::Clos(FoldedClos::build(ClosSpec::with_tiles(1024)).unwrap());
        let m = model();
        let tiles = [5usize, 17, 300, 999];
        let lut = m.access_lut(&topo, 0, tiles.iter().copied());
        assert_eq!(lut.len(), tiles.len());
        for (i, &t) in tiles.iter().enumerate() {
            assert_eq!(lut[i].to_bits(), m.access(&topo, 0, t).to_bits());
        }
    }

    #[test]
    fn route_open_saves_topen_per_switch() {
        let topo = Topology::Clos(FoldedClos::build(ClosSpec::with_tiles(1024)).unwrap());
        let closed = model();
        let mut opened = model();
        opened.net.route_open = true;
        let r = topo.route(0, 300);
        let diff = closed.round_trip(&r) - opened.round_trip(&r);
        assert_eq!(diff, 2.0 * 5.0 * r.switches() as f64);
    }
}
