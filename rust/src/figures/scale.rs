//! Scale figure (extension; not in the paper): zero-load slowdown and
//! the fitted contention factor `c_cont` from 1,024 to 1,048,576 tiles
//! on both topologies — the figure the 4,096-tile ceiling used to make
//! impossible.
//!
//! Every point past [`crate::topology::MAX_TABLE_SWITCHES`] switches
//! is only evaluable because routing is *computed*
//! ([`crate::topology::NextHop`]): O(V) router state instead of the
//! O(V²) dense table, bit-identical to that table wherever both exist.
//! Each row records the switch count, recursion depth, router memory
//! and whether the dense table is even feasible, next to the exact
//! zero-load latency, the Dhrystone-mix slowdown prediction and a
//! crowded DES measurement (the [`CLIENTS`]-client uniform scenario,
//! reusing the contention lab's cell machinery and canonical seeding —
//! so any `--jobs` count is bit-identical and the figure joins the
//! golden harness).

use std::collections::HashMap;

use anyhow::{Context, Result};

use super::contention::{cell_seed, eval_cell, Cell};
use super::{topo_str, FigOpts};
use crate::api::{DesignPoint, Report, Row};
use crate::coordinator::{ParallelSweep, SweepPoint};
use crate::emulation::{EmulationSetup, SequentialMachine, TopologyKind};
use crate::sim::contention::ContentionStats;
use crate::topology::{Topology, MAX_TABLE_SWITCHES};
use crate::util::plot::Plot;
use crate::util::table::{f, Table};
use crate::workload::{predict_slowdown, DHRYSTONE_MIX};

/// System sizes plotted: 1K to 1M tiles, both topologies at every size.
pub const SYSTEMS: &[usize] =
    &[1 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20];

/// Tile memory used (full-emulation points, like Fig 9/10).
pub const MEM_KB: u32 = 128;

/// Concurrent clients in the DES leg of every cell.
pub const CLIENTS: usize = 8;

/// Access budget per client in the DES leg.
pub const ACCESSES: usize = 192;

/// One evaluated scale point.
#[derive(Clone, Debug)]
pub struct ScaleRow {
    /// The design point (full emulation: `k = tiles - 1`).
    pub point: SweepPoint,
    /// Switches in the interconnect graph.
    pub switches: usize,
    /// Recursive system-core bank levels (0 for meshes and
    /// single-chip Clos, 1 for the paper's 1,024–8,192-tile systems,
    /// more past `degree` chips).
    pub sys_levels: usize,
    /// Whether the dense routing table could even be built here
    /// (`switches <= MAX_TABLE_SWITCHES`).
    pub table_feasible: bool,
    /// Resident bytes of the computed next-hop router (O(V)).
    pub nexthop_bytes: usize,
    /// Exact expected zero-load access latency (cycles).
    pub zero_load: f64,
    /// Dhrystone-mix slowdown prediction at that latency.
    pub slowdown: f64,
    /// The crowded uniform DES measurement ([`CLIENTS`] clients x
    /// [`ACCESSES`] accesses).
    pub stats: ContentionStats,
}

impl ScaleRow {
    /// Report/row name: `clos-1048576`.
    pub fn name(&self) -> String {
        format!("{}-{}", topo_str(self.point.kind), self.point.tiles)
    }
}

/// The figure's dataset.
#[derive(Clone, Debug)]
pub struct FigScale {
    /// One row per (system, topology), in grid order.
    pub rows: Vec<ScaleRow>,
}

/// The figure's cell grid, in generation order: every system size on
/// both topologies, as uniform contention cells (the contention lab's
/// canonical seeding makes each cell's DES stream a pure function of
/// the sweep seed and the cell identity).
pub fn grid_cells() -> Vec<Cell> {
    let mut cells = Vec::new();
    for &tiles in SYSTEMS {
        for kind in [TopologyKind::Clos, TopologyKind::Mesh] {
            let point = SweepPoint { kind, tiles, mem_kb: MEM_KB, k: tiles - 1 };
            cells.push(Cell {
                point,
                pattern: crate::workload::trace::TracePattern::Uniform,
                clients: CLIENTS,
                accesses: ACCESSES,
            });
        }
    }
    cells
}

/// Evaluate a cell list: design points are built once per unique
/// point (computed routing — no dense table at any size), then cells
/// fan out across the worker pool and come back in input order.
pub fn eval_points(engine: &ParallelSweep, cells: &[Cell]) -> Result<Vec<ScaleRow>> {
    let mut setups: HashMap<u64, EmulationSetup> = HashMap::new();
    for cell in cells {
        let key = cell.point.canonical_key();
        if !setups.contains_key(&key) {
            let p = cell.point;
            let setup = DesignPoint::new(p.kind, p.tiles)
                .mem_kb(p.mem_kb)
                .k(p.k)
                .tech(engine.tech())
                .build()
                .with_context(|| format!("building scale point {p:?}"))?;
            setups.insert(key, setup);
        }
    }
    let dram = SequentialMachine::with_measured_dram(1).dram_ns;
    engine.map(cells, |cell| {
        let setup = setups
            .get(&cell.point.canonical_key())
            .context("scale point missing from the setup table")?;
        let routes = setup.topo.next_hops();
        let switches = routes.switches();
        let zero_load = setup.expected_latency();
        Ok(ScaleRow {
            point: cell.point,
            switches,
            sys_levels: match &setup.topo {
                Topology::Clos(c) => c.spec().sys_levels(),
                Topology::Mesh(_) => 0,
            },
            table_feasible: switches <= MAX_TABLE_SWITCHES,
            nexthop_bytes: routes.memory_bytes(),
            zero_load,
            slowdown: predict_slowdown(&DHRYSTONE_MIX, zero_load, dram),
            stats: eval_cell(setup, cell, cell_seed(engine.seed(), cell))?,
        })
    })
}

/// Generate the scale dataset on a shared sweep engine.
pub fn generate_with(engine: &ParallelSweep) -> Result<FigScale> {
    Ok(FigScale { rows: eval_points(engine, &grid_cells())? })
}

/// Generate the dataset (standalone: a fresh engine).
pub fn generate(opts: &FigOpts) -> Result<FigScale> {
    generate_with(&opts.engine())
}

/// One report row — the schema `memclos figures --all --json` emits
/// for this figure and the golden harness pins.
pub fn row_for(r: &ScaleRow) -> Row {
    let s = &r.stats;
    Row::new(&r.name())
        .int("system", r.point.tiles as u64)
        .str("topo", topo_str(r.point.kind))
        .int("k", r.point.k as u64)
        .int("switches", r.switches as u64)
        .int("sys_levels", r.sys_levels as u64)
        .int("table_feasible", u64::from(r.table_feasible))
        .int("nexthop_bytes", r.nexthop_bytes as u64)
        .num("zero_load_cycles", r.zero_load)
        .num("slowdown", r.slowdown)
        .int("clients", CLIENTS as u64)
        .num("mean_cycles", s.latency.mean())
        .num("p99", s.dist.p99)
        .num("c_cont", s.c_cont)
        .num("wait_mean_cycles", s.wait.mean())
        .int("makespan_cycles", s.makespan)
}

/// Full numeric output for the golden harness.
pub fn report(fig: &FigScale) -> Report {
    let mut rep = Report::new("scale");
    for r in &fig.rows {
        rep.push(row_for(r));
    }
    rep
}

/// Render the dataset as a table plus slowdown and `c_cont` vs tiles
/// plots (one series per topology).
pub fn render(fig: &FigScale) -> String {
    let mut out = String::new();
    let mut t = Table::new(&[
        "system", "topo", "switches", "levels", "router KiB", "table?", "zero-load cy",
        "slowdown", "c_cont", "wait cy",
    ])
    .with_title("Scale: slowdown and c_cont, 1K to 1M tiles (computed routing)");
    for r in &fig.rows {
        t.row(&[
            r.point.tiles.to_string(),
            topo_str(r.point.kind).to_string(),
            r.switches.to_string(),
            r.sys_levels.to_string(),
            (r.nexthop_bytes / 1024).to_string(),
            if r.table_feasible { "yes" } else { "no" }.to_string(),
            f(r.zero_load, 1),
            f(r.slowdown, 3),
            f(r.stats.c_cont, 3),
            f(r.stats.wait.mean(), 1),
        ]);
    }
    out.push_str(&t.render());
    for (title, y, pick) in [
        (
            "Scale: Dhrystone slowdown vs tiles (log2)",
            "slowdown",
            (|r: &ScaleRow| r.slowdown) as fn(&ScaleRow) -> f64,
        ),
        (
            "Scale: c_cont (8 clients, uniform) vs tiles (log2)",
            "c_cont",
            |r: &ScaleRow| r.stats.c_cont,
        ),
    ] {
        let mut plot = Plot::new(title, "tiles (log2)", y);
        for kind in [TopologyKind::Clos, TopologyKind::Mesh] {
            let pts: Vec<(f64, f64)> = fig
                .rows
                .iter()
                .filter(|r| r.point.kind == kind)
                .map(|r| ((r.point.tiles as f64).log2(), pick(r)))
                .collect();
            plot.series(topo_str(kind), &pts);
        }
        out.push('\n');
        out.push_str(&plot.render());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Mode, Tech};
    use crate::workload::trace::TracePattern;

    /// The debug-affordable subset: the two table-era sizes on both
    /// topologies (the full 1M grid runs in the release-mode golden
    /// harness and `benches/scale.rs`).
    fn small_cells() -> Vec<Cell> {
        grid_cells().into_iter().filter(|c| c.point.tiles <= 4096).collect()
    }

    #[test]
    fn grid_covers_both_topologies_up_to_a_million_tiles() {
        let cells = grid_cells();
        assert_eq!(cells.len(), SYSTEMS.len() * 2);
        for kind in [TopologyKind::Clos, TopologyKind::Mesh] {
            assert!(cells
                .iter()
                .any(|c| c.point.kind == kind && c.point.tiles == 1 << 20));
        }
        // Cell seeds stay canonical on this grid too.
        let a = cell_seed(1, &cells[0]);
        assert_eq!(a, cell_seed(1, &cells[0]));
        for other in &cells[1..] {
            assert_ne!(a, cell_seed(1, other), "cell seed collision with {other:?}");
        }
    }

    #[test]
    fn rows_are_jobs_invariant() {
        // Satellite: the scale grid is bit-identical at any job count
        // (same canonical seeding contract as the contention lab).
        let cells = small_cells();
        let seq =
            eval_points(&ParallelSweep::new(Mode::Exact, &Tech::default(), 1, 3), &cells).unwrap();
        let par =
            eval_points(&ParallelSweep::new(Mode::Exact, &Tech::default(), 8, 3), &cells).unwrap();
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.point.canonical_key(), b.point.canonical_key());
            assert_eq!(a.zero_load.to_bits(), b.zero_load.to_bits());
            assert_eq!(a.slowdown.to_bits(), b.slowdown.to_bits());
            assert_eq!(a.stats.latency.mean().to_bits(), b.stats.latency.mean().to_bits());
            assert_eq!(a.stats.c_cont.to_bits(), b.stats.c_cont.to_bits());
            assert_eq!(a.stats.makespan, b.stats.makespan);
        }
    }

    #[test]
    fn past_the_table_ceiling_points_still_evaluate() {
        // A 65,536-tile Clos recurses two bank levels and exceeds the
        // dense-table switch ceiling — exactly the design point the old
        // code could not express. It must evaluate end to end on
        // computed routing alone.
        let engine = ParallelSweep::new(Mode::Exact, &Tech::default(), 2, 0xC105);
        let point = SweepPoint {
            kind: TopologyKind::Clos,
            tiles: 1 << 16,
            mem_kb: MEM_KB,
            k: (1 << 16) - 1,
        };
        let cells = vec![Cell {
            point,
            pattern: TracePattern::Uniform,
            clients: CLIENTS,
            accesses: 96,
        }];
        let rows = eval_points(&engine, &cells).unwrap();
        let r = &rows[0];
        assert!(r.switches > MAX_TABLE_SWITCHES && !r.table_feasible);
        assert_eq!(r.sys_levels, 2);
        // Router memory is O(V): far below what the dense table would
        // need (~4 * switches^2 bytes), and the table really is
        // unbuildable here.
        assert!(r.nexthop_bytes < r.switches * 64, "router bytes {}", r.nexthop_bytes);
        let setup = DesignPoint::clos(1 << 16).build().unwrap();
        assert!(setup.topo.try_routing_table().is_err());
        assert!(r.zero_load > 0.0 && r.slowdown > 0.0);
        assert!(r.stats.c_cont >= 1.0 - 1e-9);
        assert!(r.stats.latency.mean() >= r.stats.zero_load_mean - 1e-9);
    }

    #[test]
    fn report_rows_round_trip_their_fields() {
        let engine = ParallelSweep::new(Mode::Exact, &Tech::default(), 2, 7);
        let cells: Vec<Cell> =
            small_cells().into_iter().filter(|c| c.point.tiles == 1024).collect();
        let rows = eval_points(&engine, &cells).unwrap();
        let rendered = report(&FigScale { rows: rows.clone() }).render();
        assert!(rendered.starts_with("{\"bench\": \"scale\", \"results\": ["));
        for r in &rows {
            for needle in [
                format!("\"name\": \"{}\"", r.name()),
                format!("\"switches\": {}", r.switches),
                format!("\"table_feasible\": {}", u64::from(r.table_feasible)),
                format!("\"zero_load_cycles\": {:.4}", r.zero_load),
                format!("\"slowdown\": {:.4}", r.slowdown),
                format!("\"c_cont\": {:.4}", r.stats.c_cont),
            ] {
                assert!(rendered.contains(&needle), "missing `{needle}` in {rendered}");
            }
        }
        // The rendered text output carries the table and both plots.
        let text = render(&FigScale { rows });
        assert!(text.contains("slowdown"));
        assert!(text.contains("c_cont"));
    }
}
