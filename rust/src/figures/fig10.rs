//! Fig 10: Dhrystone and compiler benchmark slowdown (relative to the
//! sequential machine) vs emulation size, 1,024- and 4,096-tile
//! systems.

use anyhow::Result;

use super::fig9::{k_points, MEM_KB, SYSTEMS};
use super::FigOpts;
use crate::coordinator::{run_sweep, SweepPoint};
use crate::emulation::{SequentialMachine, TopologyKind};
use crate::util::plot::Plot;
use crate::util::table::{f, Table};
use crate::workload::{predict_slowdown, InstructionMix, COMPILER_MIX, DHRYSTONE_MIX};

/// One data point.
#[derive(Clone, Copy, Debug)]
pub struct Row {
    /// System tiles.
    pub system: usize,
    /// "clos" or "mesh".
    pub topo: &'static str,
    /// "dhrystone" or "compiler".
    pub benchmark: &'static str,
    /// Emulation size.
    pub k: usize,
    /// Slowdown vs the sequential machine.
    pub slowdown: f64,
}

/// Generate the Fig 10 dataset.
pub fn generate(opts: &FigOpts) -> Result<Vec<Row>> {
    let mut points = Vec::new();
    for &system in SYSTEMS {
        for kind in [TopologyKind::Clos, TopologyKind::Mesh] {
            for k in k_points(system) {
                points.push(SweepPoint { kind, tiles: system, mem_kb: MEM_KB, k });
            }
        }
    }
    let results = run_sweep(&points, opts.mode, &opts.tech, opts.workers, opts.seed)?;
    let dram = SequentialMachine::with_measured_dram(1).dram_ns;

    let benches: [(&'static str, InstructionMix); 2] =
        [("dhrystone", DHRYSTONE_MIX), ("compiler", COMPILER_MIX)];
    let mut rows = Vec::new();
    for r in &results {
        for (name, mix) in benches {
            rows.push(Row {
                system: r.point.tiles,
                topo: match r.point.kind {
                    TopologyKind::Clos => "clos",
                    TopologyKind::Mesh => "mesh",
                },
                benchmark: name,
                k: r.point.k,
                slowdown: predict_slowdown(&mix, r.mean_cycles, dram),
            });
        }
    }
    rows.sort_by_key(|r| (r.system, r.topo, r.benchmark, r.k));
    Ok(rows)
}

/// Render the dataset.
pub fn render(rows: &[Row]) -> String {
    let mut out = String::new();
    let mut t = Table::new(&["system", "topo", "benchmark", "k tiles", "slowdown"])
        .with_title("Fig 10: benchmark slowdown vs sequential machine");
    for r in rows {
        t.row(&[
            r.system.to_string(),
            r.topo.to_string(),
            r.benchmark.to_string(),
            r.k.to_string(),
            f(r.slowdown, 3),
        ]);
    }
    out.push_str(&t.render());
    for &system in SYSTEMS {
        let mut plot = Plot::new(
            &format!("Fig 10 ({system}-tile system): slowdown vs emulation tiles (log2)"),
            "emulation tiles",
            "slowdown",
        );
        for topo in ["clos", "mesh"] {
            for bench in ["dhrystone", "compiler"] {
                let pts: Vec<(f64, f64)> = rows
                    .iter()
                    .filter(|r| r.system == system && r.topo == topo && r.benchmark == bench)
                    .map(|r| (r.k as f64, r.slowdown))
                    .collect();
                plot.series(&format!("{topo}-{bench}"), &pts);
            }
        }
        plot.hline(1.0, "parity");
        out.push('\n');
        out.push_str(&plot.render());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_shape_holds() {
        let rows = generate(&FigOpts::default()).unwrap();

        // §7.2: up to 16 tiles the emulation is FASTER than the
        // sequential machine (slowdown < 1).
        let small = rows
            .iter()
            .find(|r| r.system == 1024 && r.topo == "clos" && r.benchmark == "dhrystone" && r.k == 16)
            .unwrap();
        assert!(small.slowdown < 1.0, "small-k slowdown {}", small.slowdown);

        // §7.2: folded-Clos slowdown ~2-3 up to 4,096 tiles.
        for &system in SYSTEMS {
            for bench in ["dhrystone", "compiler"] {
                let full = rows
                    .iter()
                    .filter(|r| r.system == system && r.topo == "clos" && r.benchmark == bench)
                    .last()
                    .unwrap();
                // Paper: "approximately 2 to 3"; our interposer model
                // is slightly more conservative at 16 chips, so accept
                // up to 3.3 (measured values recorded in
                // EXPERIMENTS.md).
                assert!(
                    full.slowdown > 1.5 && full.slowdown < 3.3,
                    "{bench}@{system}: slowdown {}",
                    full.slowdown
                );
            }
        }

        // §7.2: Dhrystone is less efficient (higher global fraction).
        let d = rows
            .iter()
            .find(|r| r.system == 4096 && r.topo == "clos" && r.benchmark == "dhrystone" && r.k == 4095)
            .unwrap();
        let c = rows
            .iter()
            .find(|r| r.system == 4096 && r.topo == "clos" && r.benchmark == "compiler" && r.k == 4095)
            .unwrap();
        assert!(d.slowdown > c.slowdown);

        // §7.2: mesh tracks clos at small k, deteriorates at scale.
        let mesh_small = rows
            .iter()
            .find(|r| r.system == 1024 && r.topo == "mesh" && r.benchmark == "compiler" && r.k == 64)
            .unwrap();
        let clos_small = rows
            .iter()
            .find(|r| r.system == 1024 && r.topo == "clos" && r.benchmark == "compiler" && r.k == 64)
            .unwrap();
        assert!((mesh_small.slowdown / clos_small.slowdown - 1.0).abs() < 0.35);
    }
}
