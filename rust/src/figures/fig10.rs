//! Fig 10: benchmark slowdown (relative to the sequential machine) vs
//! emulation size, 1,024- and 4,096-tile systems.
//!
//! Two kinds of rows, labelled in the `source` column:
//!
//! * **`analytic`** — the Dhrystone/compiler instruction-mix rows,
//!   computed with the closed-form [`predict_slowdown`] formula at
//!   every sweep point. These are *predictions from Fig 8's mixes*,
//!   not executions; they survive as the oracle the measurement is
//!   sanity-checked against.
//! * **`measured`** — the full `cc` corpus compiled, predecoded and
//!   **executed end-to-end** on both machines
//!   ([`crate::workload::measured`]) at the full-emulation point of
//!   each system/topology, one row per program plus the cycle-weighted
//!   `corpus` aggregate. This is the paper's §7.2 methodology: the
//!   slowdown is what the costed interpreter actually charges.

use anyhow::Result;

use super::fig9::{MEM_KB, SYSTEMS};
use super::{topo_str, FigOpts};
use crate::api::{DesignPoint, Report};
use crate::coordinator::ParallelSweep;
use crate::emulation::{EmulationSetup, SequentialMachine, TopologyKind};
use crate::util::plot::Plot;
use crate::util::table::{f, Table};
use crate::workload::measured::{CompiledCorpus, CorpusMeasurement};
use crate::workload::{predict_slowdown, InstructionMix, COMPILER_MIX, DHRYSTONE_MIX};

/// One data point.
#[derive(Clone, Copy, Debug)]
pub struct Row {
    /// System tiles.
    pub system: usize,
    /// "clos" or "mesh".
    pub topo: &'static str,
    /// "dhrystone"/"compiler" (analytic) or a corpus program name /
    /// "corpus" aggregate (measured).
    pub benchmark: &'static str,
    /// Emulation size.
    pub k: usize,
    /// Slowdown vs the sequential machine.
    pub slowdown: f64,
    /// "analytic" (mix formula) or "measured" (executed corpus).
    pub source: &'static str,
}

/// Generate the Fig 10 dataset on a shared sweep engine: the analytic
/// sweep reuses fig 9's latency points (served from the result cache
/// when the engine is shared), and the measured corpus runs fan out
/// across the pool one `(design point, program)` pair at a time —
/// integer-deterministic interpreters, so any `--jobs` is
/// bit-identical.
pub fn generate_with(engine: &ParallelSweep) -> Result<Vec<Row>> {
    let results = engine.eval_points(&super::fig9::sweep_points())?;
    let dram = SequentialMachine::with_measured_dram(1).dram_ns;

    let benches: [(&'static str, InstructionMix); 2] =
        [("dhrystone", DHRYSTONE_MIX), ("compiler", COMPILER_MIX)];
    let mut rows = Vec::new();
    for r in &results {
        for (name, mix) in benches {
            rows.push(Row {
                system: r.point.tiles,
                topo: topo_str(r.point.kind),
                benchmark: name,
                k: r.point.k,
                slowdown: predict_slowdown(&mix, r.mean_cycles, dram),
                source: "analytic",
            });
        }
    }

    // Measured rows: run the corpus through the decoded interpreter at
    // the full-emulation point of every system/topology. The corpus is
    // compiled + predecoded once; each (setup, program) pair is an
    // independent unit of work for the pool.
    let corpus = CompiledCorpus::compile()?;
    let seq = SequentialMachine::with_measured_dram(1);
    let mut setups: Vec<(usize, TopologyKind, EmulationSetup)> = Vec::new();
    for &system in SYSTEMS {
        for kind in [TopologyKind::Clos, TopologyKind::Mesh] {
            let setup = DesignPoint::new(kind, system)
                .mem_kb(MEM_KB)
                .k(system - 1)
                .tech(engine.tech())
                .build()?;
            setups.push((system, kind, setup));
        }
    }
    let n_progs = corpus.programs.len();
    let items: Vec<(usize, usize)> =
        (0..setups.len()).flat_map(|s| (0..n_progs).map(move |p| (s, p))).collect();
    let runs = engine.map(&items, |&(s, p)| corpus.measure_one(p, &setups[s].2, seq))?;
    for (s, chunk) in runs.chunks(n_progs).enumerate() {
        let (system, kind) = (setups[s].0, setups[s].1);
        let k = system - 1;
        let m = CorpusMeasurement::from_runs(chunk.to_vec());
        for run in &m.runs {
            rows.push(Row {
                system,
                topo: topo_str(kind),
                benchmark: run.name,
                k,
                slowdown: run.slowdown(),
                source: "measured",
            });
        }
        rows.push(Row {
            system,
            topo: topo_str(kind),
            benchmark: "corpus",
            k,
            slowdown: m.slowdown(),
            source: "measured",
        });
    }

    rows.sort_by_key(|r| (r.system, r.topo, r.source, r.benchmark, r.k));
    Ok(rows)
}

/// Generate the Fig 10 dataset (standalone: a fresh engine).
pub fn generate(opts: &FigOpts) -> Result<Vec<Row>> {
    generate_with(&opts.engine())
}

/// Full numeric output for the golden harness.
pub fn report(rows: &[Row]) -> Report {
    let mut rep = Report::new("fig10");
    for r in rows {
        rep.push(
            crate::api::Row::new(&format!("{}-{}t-{}-k{}", r.topo, r.system, r.benchmark, r.k))
                .int("system", r.system as u64)
                .int("k", r.k as u64)
                .str("source", r.source)
                .num("slowdown", r.slowdown),
        );
    }
    rep
}

/// Render the dataset.
pub fn render(rows: &[Row]) -> String {
    let mut out = String::new();
    let mut t = Table::new(&["system", "topo", "benchmark", "source", "k tiles", "slowdown"])
        .with_title("Fig 10: benchmark slowdown vs sequential machine");
    for r in rows {
        t.row(&[
            r.system.to_string(),
            r.topo.to_string(),
            r.benchmark.to_string(),
            r.source.to_string(),
            r.k.to_string(),
            f(r.slowdown, 3),
        ]);
    }
    out.push_str(&t.render());
    out.push_str(
        "\nanalytic rows: closed-form mix prediction (oracle); measured rows: \
         the cc corpus executed end-to-end on both machines.\n",
    );
    for &system in SYSTEMS {
        let mut plot = Plot::new(
            &format!("Fig 10 ({system}-tile system): slowdown vs emulation tiles (log2)"),
            "emulation tiles",
            "slowdown",
        );
        for topo in ["clos", "mesh"] {
            for bench in ["dhrystone", "compiler"] {
                let pts: Vec<(f64, f64)> = rows
                    .iter()
                    .filter(|r| {
                        r.system == system
                            && r.topo == topo
                            && r.benchmark == bench
                            && r.source == "analytic"
                    })
                    .map(|r| (r.k as f64, r.slowdown))
                    .collect();
                plot.series(&format!("{topo}-{bench} (analytic)"), &pts);
            }
        }
        plot.hline(1.0, "parity");
        out.push('\n');
        out.push_str(&plot.render());
        for topo in ["clos", "mesh"] {
            if let Some(r) = rows.iter().find(|r| {
                r.system == system && r.topo == topo && r.benchmark == "corpus"
            }) {
                out.push_str(&format!(
                    "measured corpus slowdown ({topo}, k={}): {}x\n",
                    r.k,
                    f(r.slowdown, 2)
                ));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_shape_holds() {
        let rows = generate(&FigOpts::default()).unwrap();

        // §7.2: up to 16 tiles the emulation is FASTER than the
        // sequential machine (slowdown < 1).
        let small = rows
            .iter()
            .find(|r| r.system == 1024 && r.topo == "clos" && r.benchmark == "dhrystone" && r.k == 16)
            .unwrap();
        assert!(small.slowdown < 1.0, "small-k slowdown {}", small.slowdown);

        // §7.2: folded-Clos slowdown ~2-3 up to 4,096 tiles.
        for &system in SYSTEMS {
            for bench in ["dhrystone", "compiler"] {
                let full = rows
                    .iter()
                    .filter(|r| r.system == system && r.topo == "clos" && r.benchmark == bench)
                    .last()
                    .unwrap();
                // Paper: "approximately 2 to 3"; our interposer model
                // is slightly more conservative at 16 chips, so accept
                // up to 3.3 (measured values recorded in
                // EXPERIMENTS.md).
                assert!(
                    full.slowdown > 1.5 && full.slowdown < 3.3,
                    "{bench}@{system}: slowdown {}",
                    full.slowdown
                );
            }
        }

        // §7.2: Dhrystone is less efficient (higher global fraction).
        let d = rows
            .iter()
            .find(|r| r.system == 4096 && r.topo == "clos" && r.benchmark == "dhrystone" && r.k == 4095)
            .unwrap();
        let c = rows
            .iter()
            .find(|r| r.system == 4096 && r.topo == "clos" && r.benchmark == "compiler" && r.k == 4095)
            .unwrap();
        assert!(d.slowdown > c.slowdown);

        // §7.2: mesh tracks clos at small k, deteriorates at scale.
        let mesh_small = rows
            .iter()
            .find(|r| r.system == 1024 && r.topo == "mesh" && r.benchmark == "compiler" && r.k == 64)
            .unwrap();
        let clos_small = rows
            .iter()
            .find(|r| r.system == 1024 && r.topo == "clos" && r.benchmark == "compiler" && r.k == 64)
            .unwrap();
        assert!((mesh_small.slowdown / clos_small.slowdown - 1.0).abs() < 0.35);
    }

    #[test]
    fn measured_rows_cover_the_corpus() {
        let rows = generate(&FigOpts::default()).unwrap();
        // Every row is labelled.
        assert!(rows.iter().all(|r| r.source == "analytic" || r.source == "measured"));
        // Measured rows at the full-emulation point of both systems
        // and both topologies, with the per-program + aggregate rows.
        let n_corpus = crate::cc::corpus::all().len();
        for &system in SYSTEMS {
            for topo in ["clos", "mesh"] {
                let measured: Vec<&Row> = rows
                    .iter()
                    .filter(|r| r.system == system && r.topo == topo && r.source == "measured")
                    .collect();
                assert_eq!(measured.len(), n_corpus + 1, "{topo}@{system}");
                assert!(measured.iter().all(|r| r.k == system - 1));
                let agg = measured.iter().find(|r| r.benchmark == "corpus").unwrap();
                // Full emulation: slower than the sequential machine
                // but within the paper's broad band.
                assert!(
                    agg.slowdown > 1.0 && agg.slowdown < 6.0,
                    "{topo}@{system}: measured corpus slowdown {}",
                    agg.slowdown
                );
            }
        }
        // The analytic compiler-mix prediction and the measured corpus
        // aggregate tell the same story at the 4,096-tile Clos point.
        let analytic = rows
            .iter()
            .find(|r| {
                r.system == 4096 && r.topo == "clos" && r.benchmark == "compiler" && r.k == 4095
            })
            .unwrap();
        let measured = rows
            .iter()
            .find(|r| {
                r.system == 4096 && r.topo == "clos" && r.benchmark == "corpus" && r.k == 4095
            })
            .unwrap();
        let rel = (measured.slowdown / analytic.slowdown - 1.0).abs();
        assert!(
            rel < 0.6,
            "measured {} vs analytic {} diverge by {rel}",
            measured.slowdown,
            analytic.slowdown
        );
    }

    #[test]
    fn render_labels_sources() {
        let rows = vec![
            Row {
                system: 1024,
                topo: "clos",
                benchmark: "dhrystone",
                k: 16,
                slowdown: 0.9,
                source: "analytic",
            },
            Row {
                system: 1024,
                topo: "clos",
                benchmark: "corpus",
                k: 1023,
                slowdown: 2.4,
                source: "measured",
            },
        ];
        let s = render(&rows);
        assert!(s.contains("source"));
        assert!(s.contains("analytic"));
        assert!(s.contains("measured corpus slowdown (clos, k=1023)"));
    }
}
