//! Contention figure (extension; not in the paper): the fitted
//! contention factor `c_cont` and its tail latencies across a clients ×
//! pattern grid at the 1,024- and 4,096-tile full-emulation Clos
//! points.
//!
//! The paper abstracts multi-client interference into a single fitted
//! `c_cont` measured under uniform traffic only (§6.3). This figure
//! measures it per access pattern — uniform, zipf hot-spot, sequential
//! stride, pointer chase, phased working set — and per crowd size, with
//! the full latency distribution (mean/p50/p95/p99/max), per-access
//! queue waiting and port occupancy next to the fitted factor.
//!
//! Every cell is ONE causally-dependent DES timeline
//! ([`crate::sim::contention::run_scenario`]), inherently sequential;
//! the grid fans out across cells on the [`ParallelSweep`] engine. A
//! cell's RNG streams are seeded through [`point_seed`] from the sweep
//! seed and the cell's canonical identity (design point ⊕ pattern ⊕
//! clients ⊕ accesses) — never from scheduling — so any `--jobs` count
//! is bit-identical to the sequential pass, and the whole figure joins
//! the golden harness. The `uniform` column is the legacy
//! [`crate::sim::network::run_contention`] experiment bit for bit (the
//! oracle rule; proven in the tests below).

use std::collections::HashMap;

use anyhow::{Context, Result};

use super::{topo_str, FigOpts};
use crate::api::{DesignPoint, Report, Row};
use crate::coordinator::{point_seed, ParallelSweep, SweepPoint};
use crate::emulation::{EmulationSetup, TopologyKind};
use crate::sim::contention::{run_scenario, ContentionStats, Workload};
use crate::util::plot::Plot;
use crate::util::table::{f, Table};
use crate::workload::trace::{Trace, TracePattern};

/// Systems plotted (full-emulation Clos points, like Fig 9/10).
pub const SYSTEMS: &[usize] = &[1024, 4096];

/// Tile memory used.
pub const MEM_KB: u32 = 128;

/// Crowd sizes per cell.
pub const CLIENTS: &[usize] = &[1, 8, 64];

/// Access budget per client per cell.
pub const ACCESSES: usize = 400;

/// The pattern catalogue of the figure, parameterised for a design
/// point whose memory tiles hold `block_words` words: the stride walks
/// one block plus one word per access (round-robin over the memory
/// tiles), the zipf hot spot and phased windows use their defaults.
pub fn patterns(block_words: u64) -> Vec<TracePattern> {
    vec![
        TracePattern::Uniform,
        TracePattern::Zipf { theta: 1.2 },
        TracePattern::Stride { stride: block_words + 1 },
        TracePattern::PointerChase,
        TracePattern::Phased { phases: 4, frac: 1.0 / 16.0 },
    ]
}

/// Words each memory tile of a sweep point holds (32-bit words:
/// `mem_kb` KB = `mem_kb * 256` words — the [`DesignPoint`] invariant).
pub fn block_words(point: &SweepPoint) -> u64 {
    point.mem_kb as u64 * 256
}

/// One grid cell: a design point replaying one pattern with one crowd
/// size. The unit the sweep engine maps over.
#[derive(Clone, Copy, Debug)]
pub struct Cell {
    /// The design point.
    pub point: SweepPoint,
    /// Access pattern every client replays.
    pub pattern: TracePattern,
    /// Concurrent clients.
    pub clients: usize,
    /// Accesses per client.
    pub accesses: usize,
}

/// The canonical per-cell seed: a pure function of the sweep seed and
/// the cell's identity (never of worker count or arrival order — the
/// determinism contract every sweep consumer follows).
pub fn cell_seed(sweep_seed: u64, cell: &Cell) -> u64 {
    point_seed(
        point_seed(sweep_seed, cell.point.canonical_key()),
        cell.pattern.key() ^ ((cell.clients as u64) << 1) ^ ((cell.accesses as u64) << 24),
    )
}

/// One evaluated cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    /// The design point.
    pub point: SweepPoint,
    /// Pattern label (`uniform`, `zipf`, ... or `trace:<prog>` for the
    /// CLI's captured-trace scenarios).
    pub pattern: String,
    /// Concurrent clients.
    pub clients: usize,
    /// Everything the scenario measured.
    pub stats: ContentionStats,
}

impl CellResult {
    /// Report/row name: `clos-1024-zipf-c8`.
    pub fn name(&self) -> String {
        format!(
            "{}-{}-{}-c{}",
            topo_str(self.point.kind),
            self.point.tiles,
            self.pattern,
            self.clients
        )
    }
}

/// Evaluate one cell against a prebuilt setup. The `uniform` pattern
/// runs the shared on-line stream (the legacy-oracle path); every other
/// pattern generates one trace per client, seeded per client from the
/// cell seed.
pub fn eval_cell(setup: &EmulationSetup, cell: &Cell, seed: u64) -> Result<ContentionStats> {
    match cell.pattern {
        TracePattern::Uniform => {
            run_scenario(setup, cell.clients, cell.accesses, seed, Workload::SharedUniform)
        }
        pattern => {
            let block = 1u64 << setup.map.log2_words_per_tile;
            let traces: Vec<Trace> = (0..cell.clients)
                .map(|c| {
                    pattern.generate(
                        setup.map.space_words(),
                        block,
                        cell.accesses,
                        point_seed(seed, c as u64 + 1),
                    )
                })
                .collect();
            run_scenario(setup, cell.clients, cell.accesses, seed, Workload::Traces(&traces))
        }
    }
}

/// Evaluate a cell grid on the sweep engine: design points are built
/// once per unique point, cells fan out across the worker pool (one DES
/// timeline each) and come back in input order — bit-identical at any
/// job count.
pub fn eval_cells(engine: &ParallelSweep, cells: &[Cell]) -> Result<Vec<CellResult>> {
    let mut setups: HashMap<u64, EmulationSetup> = HashMap::new();
    for cell in cells {
        let key = cell.point.canonical_key();
        if !setups.contains_key(&key) {
            let p = cell.point;
            let setup = DesignPoint::new(p.kind, p.tiles)
                .mem_kb(p.mem_kb)
                .k(p.k)
                .tech(engine.tech())
                .build()
                .with_context(|| format!("building contention cell point {p:?}"))?;
            setups.insert(key, setup);
        }
    }
    engine.map(cells, |cell| {
        let setup = setups
            .get(&cell.point.canonical_key())
            .context("cell point missing from the setup table")?;
        Ok(CellResult {
            point: cell.point,
            pattern: cell.pattern.label().to_string(),
            clients: cell.clients,
            stats: eval_cell(setup, cell, cell_seed(engine.seed(), cell))?,
        })
    })
}

/// The figure's dataset.
#[derive(Clone, Debug)]
pub struct FigContention {
    /// One row per (system, pattern, clients) cell, in grid order.
    pub rows: Vec<CellResult>,
}

/// The figure's cell grid, in generation order.
pub fn grid_cells() -> Vec<Cell> {
    let mut cells = Vec::new();
    for &system in SYSTEMS {
        let point =
            SweepPoint { kind: TopologyKind::Clos, tiles: system, mem_kb: MEM_KB, k: system - 1 };
        for pattern in patterns(block_words(&point)) {
            for &clients in CLIENTS {
                cells.push(Cell { point, pattern, clients, accesses: ACCESSES });
            }
        }
    }
    cells
}

/// Generate the contention dataset on a shared sweep engine.
pub fn generate_with(engine: &ParallelSweep) -> Result<FigContention> {
    Ok(FigContention { rows: eval_cells(engine, &grid_cells())? })
}

/// Generate the dataset (standalone: a fresh engine).
pub fn generate(opts: &FigOpts) -> Result<FigContention> {
    generate_with(&opts.engine())
}

/// One report row for a cell — the schema `memclos contention --json`
/// and the figure share (documented in [`crate::api::report`]).
pub fn row_for(r: &CellResult) -> Row {
    let s = &r.stats;
    Row::new(&r.name())
        .int("system", r.point.tiles as u64)
        .int("k", r.point.k as u64)
        .str("pattern", &r.pattern)
        .int("clients", r.clients as u64)
        .int("accesses", s.accesses as u64)
        .int("remote_accesses", s.latency.count())
        .num("mean_cycles", s.latency.mean())
        .num("p50", s.dist.p50)
        .num("p95", s.dist.p95)
        .num("p99", s.dist.p99)
        .num("max_cycles", s.dist.max)
        .num("zero_load_cycles", s.zero_load_mean)
        .num("c_cont", s.c_cont)
        .num("inflation", s.inflation)
        .num("wait_mean_cycles", s.wait.mean())
        .num("wait_max_cycles", s.wait.max())
        .int("retries", s.retries)
        .int("timeouts", s.timeouts)
        .num("port_util_mean", s.port_util_mean)
        .num("port_util_max", s.port_util_max)
        .int("makespan_cycles", s.makespan)
}

/// Render a cell set as the machine-diffable contention report (the
/// document the golden harness pins as `contention.json`).
pub fn report_rows(rows: &[CellResult]) -> Report {
    let mut rep = Report::new("contention");
    for r in rows {
        rep.push(row_for(r));
    }
    rep
}

/// Full numeric output for the golden harness.
pub fn report(fig: &FigContention) -> Report {
    report_rows(&fig.rows)
}

/// Render the dataset as a table plus one `c_cont` vs clients plot per
/// system.
pub fn render(fig: &FigContention) -> String {
    let mut out = String::new();
    let mut t = Table::new(&[
        "system", "pattern", "clients", "mean cy", "p50", "p95", "p99", "max", "c_cont",
        "wait cy", "util max",
    ])
    .with_title("Contention lab: c_cont and tail latency vs clients x pattern");
    for r in &fig.rows {
        let s = &r.stats;
        t.row(&[
            r.point.tiles.to_string(),
            r.pattern.clone(),
            r.clients.to_string(),
            f(s.latency.mean(), 1),
            f(s.dist.p50, 1),
            f(s.dist.p95, 1),
            f(s.dist.p99, 1),
            f(s.dist.max, 0),
            f(s.c_cont, 3),
            f(s.wait.mean(), 1),
            f(s.port_util_max, 2),
        ]);
    }
    out.push_str(&t.render());
    for &system in SYSTEMS {
        let mut plot = Plot::new(
            &format!("Contention ({system}-tile Clos): c_cont vs concurrent clients"),
            "clients",
            "c_cont",
        );
        let mut labels: Vec<&str> = Vec::new();
        for r in &fig.rows {
            if r.point.tiles == system && !labels.contains(&r.pattern.as_str()) {
                labels.push(r.pattern.as_str());
            }
        }
        for label in labels {
            let pts: Vec<(f64, f64)> = fig
                .rows
                .iter()
                .filter(|r| r.point.tiles == system && r.pattern == label)
                .map(|r| (r.clients as f64, r.stats.c_cont))
                .collect();
            plot.series(label, &pts);
        }
        out.push('\n');
        out.push_str(&plot.render());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Mode, Tech};
    use crate::sim::network::run_contention;

    /// A small engine + grid the tests can afford: one 256-tile point.
    fn small_cells() -> Vec<Cell> {
        let point =
            SweepPoint { kind: TopologyKind::Clos, tiles: 256, mem_kb: 128, k: 255 };
        let mut cells = Vec::new();
        for pattern in patterns(block_words(&point)) {
            for &clients in &[1usize, 16] {
                cells.push(Cell { point, pattern, clients, accesses: 200 });
            }
        }
        cells
    }

    #[test]
    fn crowded_c_cont_dominates_solo_for_every_pattern() {
        // The acceptance criterion, on the affordable grid: for every
        // pattern the crowded fitted factor is at least the solo one.
        let engine = ParallelSweep::new(Mode::Exact, &Tech::default(), 4, 0xC105);
        let rows = eval_cells(&engine, &small_cells()).unwrap();
        for pattern in ["uniform", "zipf", "stride", "chase", "phased"] {
            let solo = rows
                .iter()
                .find(|r| r.pattern == pattern && r.clients == 1)
                .unwrap_or_else(|| panic!("missing solo {pattern}"));
            let crowd = rows
                .iter()
                .find(|r| r.pattern == pattern && r.clients == 16)
                .unwrap_or_else(|| panic!("missing crowd {pattern}"));
            assert!(
                crowd.stats.c_cont >= solo.stats.c_cont - 1e-9,
                "{pattern}: crowd c_cont {} < solo {}",
                crowd.stats.c_cont,
                solo.stats.c_cont
            );
            assert!(solo.stats.c_cont >= 1.0 - 1e-9);
            let d = &crowd.stats.dist;
            assert!(d.p50 <= d.p95 && d.p95 <= d.p99 && d.p99 <= d.max);
        }
    }

    #[test]
    fn uniform_cells_reproduce_the_legacy_oracle_bitwise() {
        // The figure's uniform column IS the legacy experiment: same
        // summary bits for the same (setup, clients, accesses, seed).
        let engine = ParallelSweep::new(Mode::Exact, &Tech::default(), 2, 0xC105);
        let point =
            SweepPoint { kind: TopologyKind::Clos, tiles: 256, mem_kb: 128, k: 255 };
        let cells: Vec<Cell> = [1usize, 8]
            .iter()
            .map(|&clients| Cell {
                point,
                pattern: TracePattern::Uniform,
                clients,
                accesses: 250,
            })
            .collect();
        let rows = eval_cells(&engine, &cells).unwrap();
        let setup = DesignPoint::new(point.kind, point.tiles)
            .mem_kb(point.mem_kb)
            .k(point.k)
            .build()
            .unwrap();
        for (cell, row) in cells.iter().zip(&rows) {
            let legacy =
                run_contention(&setup, cell.clients, cell.accesses, cell_seed(0xC105, cell));
            assert_eq!(row.stats.latency.count(), legacy.latency.count());
            assert_eq!(
                row.stats.latency.mean().to_bits(),
                legacy.latency.mean().to_bits(),
                "clients={}: uniform cell diverged from run_contention",
                cell.clients
            );
            assert_eq!(row.stats.inflation.to_bits(), legacy.inflation.to_bits());
        }
    }

    #[test]
    fn grid_covers_systems_patterns_and_crowds() {
        let cells = grid_cells();
        assert_eq!(cells.len(), SYSTEMS.len() * 5 * CLIENTS.len());
        // Cell seeds are canonical: same cell -> same seed; any
        // differing coordinate -> a different seed.
        let a = cell_seed(1, &cells[0]);
        assert_eq!(a, cell_seed(1, &cells[0]));
        for other in &cells[1..] {
            assert_ne!(a, cell_seed(1, other), "cell seed collision with {other:?}");
        }
    }

    #[test]
    fn report_rows_round_trip_their_fields() {
        // Satellite: the --json schema round-trips — every numeric
        // field lands in the rendered document exactly as the fixed
        // 4-decimal rendering of the stat it came from.
        let engine = ParallelSweep::new(Mode::Exact, &Tech::default(), 2, 7);
        let point =
            SweepPoint { kind: TopologyKind::Clos, tiles: 256, mem_kb: 128, k: 255 };
        let cells = vec![Cell {
            point,
            pattern: TracePattern::Zipf { theta: 1.2 },
            clients: 8,
            accesses: 150,
        }];
        let rows = eval_cells(&engine, &cells).unwrap();
        let rendered = report_rows(&rows).render();
        assert!(rendered.starts_with("{\"bench\": \"contention\", \"results\": ["));
        let r = &rows[0];
        let s = &r.stats;
        let field = |key: &str, want: String| {
            let needle = format!("\"{key}\": {want}");
            assert!(rendered.contains(&needle), "missing `{needle}` in {rendered}");
        };
        field("name", format!("\"{}\"", r.name()));
        field("pattern", "\"zipf\"".to_string());
        field("clients", "8".to_string());
        field("remote_accesses", s.latency.count().to_string());
        field("mean_cycles", format!("{:.4}", s.latency.mean()));
        field("p50", format!("{:.4}", s.dist.p50));
        field("p95", format!("{:.4}", s.dist.p95));
        field("p99", format!("{:.4}", s.dist.p99));
        field("max_cycles", format!("{:.4}", s.dist.max));
        field("c_cont", format!("{:.4}", s.c_cont));
        field("inflation", format!("{:.4}", s.inflation));
        field("wait_mean_cycles", format!("{:.4}", s.wait.mean()));
        field("retries", s.retries.to_string());
        field("timeouts", s.timeouts.to_string());
        field("port_util_max", format!("{:.4}", s.port_util_max));
        field("makespan_cycles", s.makespan.to_string());
    }

    #[test]
    fn cells_are_jobs_invariant() {
        let cells = small_cells();
        let seq = eval_cells(&ParallelSweep::new(Mode::Exact, &Tech::default(), 1, 3), &cells)
            .unwrap();
        let par = eval_cells(&ParallelSweep::new(Mode::Exact, &Tech::default(), 8, 3), &cells)
            .unwrap();
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.pattern, b.pattern);
            assert_eq!(a.clients, b.clients);
            assert_eq!(a.stats.latency.mean().to_bits(), b.stats.latency.mean().to_bits());
            assert_eq!(a.stats.dist, b.stats.dist);
            assert_eq!(a.stats.c_cont.to_bits(), b.stats.c_cont.to_bits());
            assert_eq!(a.stats.makespan, b.stats.makespan);
        }
    }
}
