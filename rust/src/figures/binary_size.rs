//! §7.3: program binary size — the emulated-memory backend grows the
//! binary by ~8% (loads +2 instructions, stores +3).

use anyhow::Result;

use crate::cc::{compile, corpus, Backend};
use crate::util::table::{f, Table};

/// One corpus measurement.
#[derive(Clone, Debug)]
pub struct Row {
    /// Program name.
    pub name: &'static str,
    /// Direct-backend binary size, bytes.
    pub direct_bytes: usize,
    /// Emulated-backend binary size, bytes.
    pub emulated_bytes: usize,
    /// Static global load sites.
    pub load_sites: usize,
    /// Static global store sites.
    pub store_sites: usize,
}

impl Row {
    /// Relative growth.
    pub fn overhead(&self) -> f64 {
        self.emulated_bytes as f64 / self.direct_bytes as f64 - 1.0
    }
}

/// Generate the §7.3 dataset over the corpus.
pub fn generate() -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for prog in corpus::all() {
        let d = compile(prog.source, Backend::Direct)?;
        let e = compile(prog.source, Backend::Emulated)?;
        rows.push(Row {
            name: prog.name,
            direct_bytes: d.binary_bytes(),
            emulated_bytes: e.binary_bytes(),
            load_sites: d.load_sites,
            store_sites: d.store_sites,
        });
    }
    Ok(rows)
}

/// Aggregate overhead over the whole corpus.
pub fn total_overhead(rows: &[Row]) -> f64 {
    let d: usize = rows.iter().map(|r| r.direct_bytes).sum();
    let e: usize = rows.iter().map(|r| r.emulated_bytes).sum();
    e as f64 / d as f64 - 1.0
}

/// Full numeric output for the golden harness.
pub fn report(rows: &[Row]) -> crate::api::Report {
    let mut rep = crate::api::Report::new("binary_size");
    for r in rows {
        rep.push(
            crate::api::Row::new(r.name)
                .int("direct_bytes", r.direct_bytes as u64)
                .int("emulated_bytes", r.emulated_bytes as u64)
                .int("load_sites", r.load_sites as u64)
                .int("store_sites", r.store_sites as u64)
                .num("overhead_pct", r.overhead() * 100.0),
        );
    }
    rep.push(
        crate::api::Row::new("corpus-total").num("overhead_pct", total_overhead(rows) * 100.0),
    );
    rep
}

/// Render the dataset.
pub fn render(rows: &[Row]) -> String {
    let mut t = Table::new(&[
        "program",
        "direct B",
        "emulated B",
        "loads",
        "stores",
        "overhead %",
    ])
    .with_title("Binary size: direct vs emulated-memory backend (paper: ~8%)");
    for r in rows {
        t.row(&[
            r.name.to_string(),
            r.direct_bytes.to_string(),
            r.emulated_bytes.to_string(),
            r.load_sites.to_string(),
            r.store_sites.to_string(),
            f(r.overhead() * 100.0, 1),
        ]);
    }
    let mut s = t.render();
    s.push_str(&format!("corpus total overhead: {}%\n", f(total_overhead(rows) * 100.0, 1)));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_near_paper() {
        let rows = generate().unwrap();
        assert!(rows.len() >= 5);
        let total = total_overhead(&rows);
        assert!((0.03..=0.15).contains(&total), "total overhead {total}");
        for r in &rows {
            assert!(r.overhead() > 0.0, "{}: no growth?", r.name);
            // exact accounting: 4 bytes per extra instruction
            assert_eq!(
                r.emulated_bytes - r.direct_bytes,
                4 * (2 * r.load_sites + 3 * r.store_sites),
                "{}",
                r.name
            );
        }
    }
}
