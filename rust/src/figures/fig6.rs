//! Fig 6: switch, wire and I/O area as a percentage of the die, vs
//! number of tiles (256 KB tile memories).

use anyhow::Result;

use super::topo_str;
use crate::api::{Mode, Report, Tech};
use crate::coordinator::{ParallelSweep, PlanPoint};
use crate::emulation::TopologyKind;
use crate::tech::ChipTech;
use crate::util::plot::Plot;
use crate::util::table::{f, Table};

/// One data point.
#[derive(Clone, Copy, Debug)]
pub struct Row {
    /// "clos" or "mesh".
    pub topo: &'static str,
    /// Tiles on the chip.
    pub tiles: usize,
    /// Switch-group share of the die.
    pub switch_pct: f64,
    /// Wiring-channel share of the die.
    pub wire_pct: f64,
    /// I/O share of the die.
    pub io_pct: f64,
}

/// Tile memory used by the figure.
pub const MEM_KB: u32 = 256;

/// The figure's plan grid: the fig 5 tile points at 256 KB, both
/// topologies. Every point here is already in fig 5's grid, so on a
/// shared engine this figure is served entirely from the plan cache.
pub fn plan_points() -> Vec<PlanPoint> {
    let mut pts = Vec::new();
    for &tiles in super::fig5::TILE_POINTS {
        pts.push(PlanPoint { kind: TopologyKind::Clos, tiles, mem_kb: MEM_KB });
        pts.push(PlanPoint { kind: TopologyKind::Mesh, tiles, mem_kb: MEM_KB });
    }
    pts
}

/// Generate the Fig 6 dataset on a shared sweep engine.
pub fn generate_with(engine: &ParallelSweep) -> Result<Vec<Row>> {
    let plans = engine.eval_plans(&plan_points())?;
    Ok(plans
        .iter()
        .map(|p| Row {
            topo: topo_str(p.point.kind),
            tiles: p.point.tiles,
            switch_pct: 100.0 * p.switch_area_mm2 / p.area_mm2,
            wire_pct: 100.0 * p.wire_area_mm2 / p.area_mm2,
            io_pct: 100.0 * p.io_area_mm2 / p.area_mm2,
        })
        .collect())
}

/// Generate the Fig 6 dataset (standalone: a fresh engine).
pub fn generate(tech: &ChipTech) -> Result<Vec<Row>> {
    let tech = Tech { chip: tech.clone(), ..Tech::default() };
    generate_with(&ParallelSweep::with_defaults(Mode::Exact, &tech))
}

/// Full numeric output for the golden harness.
pub fn report(rows: &[Row]) -> Report {
    let mut rep = Report::new("fig6");
    for r in rows {
        rep.push(
            crate::api::Row::new(&format!("{}-{}t", r.topo, r.tiles))
                .int("tiles", r.tiles as u64)
                .num("switch_pct", r.switch_pct)
                .num("wire_pct", r.wire_pct)
                .num("io_pct", r.io_pct),
        );
    }
    rep
}

/// Render the dataset.
pub fn render(rows: &[Row]) -> String {
    let mut t = Table::new(&["topo", "tiles", "switch %", "wire %", "I/O %", "interconnect %"])
        .with_title("Fig 6: component area share (256 KB tile memory)");
    for r in rows {
        t.row(&[
            r.topo.to_string(),
            r.tiles.to_string(),
            f(r.switch_pct, 2),
            f(r.wire_pct, 2),
            f(r.io_pct, 2),
            f(r.switch_pct + r.wire_pct, 2),
        ]);
    }
    let mut plot =
        Plot::new("Fig 6: interconnect area share (%) vs tiles (log2)", "tiles", "% of die");
    for topo in ["clos", "mesh"] {
        let pts: Vec<(f64, f64)> = rows
            .iter()
            .filter(|r| r.topo == topo)
            .map(|r| (r.tiles as f64, r.switch_pct + r.wire_pct))
            .collect();
        plot.series(&format!("{topo} switch+wire"), &pts);
        let io: Vec<(f64, f64)> =
            rows.iter().filter(|r| r.topo == topo).map(|r| (r.tiles as f64, r.io_pct)).collect();
        plot.series(&format!("{topo} io"), &io);
    }
    format!("{}\n{}", t.render(), plot.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clos_interconnect_exceeds_mesh() {
        // §5.1.2: Clos interconnect ~5-8% vs mesh 2-3% on economical
        // dies; at minimum Clos > mesh everywhere at >=64 tiles.
        let rows = generate(&ChipTech::default()).unwrap();
        for &tiles in super::super::fig5::TILE_POINTS {
            if tiles < 64 {
                continue;
            }
            let c = rows.iter().find(|r| r.topo == "clos" && r.tiles == tiles).unwrap();
            let m = rows.iter().find(|r| r.topo == "mesh" && r.tiles == tiles).unwrap();
            let ci = c.switch_pct + c.wire_pct;
            let mi = m.switch_pct + m.wire_pct;
            assert!(ci > mi, "tiles={tiles}: clos {ci} <= mesh {mi}");
        }
    }

    #[test]
    fn clos_io_share_substantial() {
        // I/O dominates small-memory Clos chips; at 256 KB it is still
        // a double-digit share at 256 tiles (paper Fig 6).
        let rows = generate(&ChipTech::default()).unwrap();
        let c256 = rows.iter().find(|r| r.topo == "clos" && r.tiles == 256).unwrap();
        assert!(c256.io_pct > 10.0, "io {}%", c256.io_pct);
        // Mesh I/O share shrinks with tiles.
        let m64 = rows.iter().find(|r| r.topo == "mesh" && r.tiles == 64).unwrap();
        let m1024 = rows.iter().find(|r| r.topo == "mesh" && r.tiles == 1024).unwrap();
        assert!(m1024.io_pct < m64.io_pct);
    }
}
