//! Fig 6: switch, wire and I/O area as a percentage of the die, vs
//! number of tiles (256 KB tile memories).

use anyhow::Result;

use crate::tech::ChipTech;
use crate::topology::{ClosSpec, MeshSpec};
use crate::util::plot::Plot;
use crate::util::table::{f, Table};
use crate::vlsi::{ClosFloorplan, MeshFloorplan};

/// One data point.
#[derive(Clone, Copy, Debug)]
pub struct Row {
    /// "clos" or "mesh".
    pub topo: &'static str,
    /// Tiles on the chip.
    pub tiles: usize,
    /// Switch-group share of the die.
    pub switch_pct: f64,
    /// Wiring-channel share of the die.
    pub wire_pct: f64,
    /// I/O share of the die.
    pub io_pct: f64,
}

/// Tile memory used by the figure.
pub const MEM_KB: u32 = 256;

/// Generate the Fig 6 dataset.
pub fn generate(tech: &ChipTech) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for &tiles in super::fig5::TILE_POINTS {
        let spec = ClosSpec { tiles, tiles_per_chip: tiles.max(256), ..ClosSpec::default() };
        let c = ClosFloorplan::plan(&spec, MEM_KB, tech)?;
        rows.push(Row {
            topo: "clos",
            tiles,
            switch_pct: 100.0 * c.switch_area_mm2 / c.area_mm2,
            wire_pct: 100.0 * c.wire_area_mm2 / c.area_mm2,
            io_pct: 100.0 * c.io_area_mm2 / c.area_mm2,
        });
        let mspec = MeshSpec::single_chip(tiles)?;
        let m = MeshFloorplan::plan(&mspec, MEM_KB, tech)?;
        rows.push(Row {
            topo: "mesh",
            tiles,
            switch_pct: 100.0 * m.switch_area_mm2 / m.area_mm2,
            wire_pct: 100.0 * m.wire_area_mm2 / m.area_mm2,
            io_pct: 100.0 * m.io_area_mm2 / m.area_mm2,
        });
    }
    Ok(rows)
}

/// Render the dataset.
pub fn render(rows: &[Row]) -> String {
    let mut t = Table::new(&["topo", "tiles", "switch %", "wire %", "I/O %", "interconnect %"])
        .with_title("Fig 6: component area share (256 KB tile memory)");
    for r in rows {
        t.row(&[
            r.topo.to_string(),
            r.tiles.to_string(),
            f(r.switch_pct, 2),
            f(r.wire_pct, 2),
            f(r.io_pct, 2),
            f(r.switch_pct + r.wire_pct, 2),
        ]);
    }
    let mut plot =
        Plot::new("Fig 6: interconnect area share (%) vs tiles (log2)", "tiles", "% of die");
    for topo in ["clos", "mesh"] {
        let pts: Vec<(f64, f64)> = rows
            .iter()
            .filter(|r| r.topo == topo)
            .map(|r| (r.tiles as f64, r.switch_pct + r.wire_pct))
            .collect();
        plot.series(&format!("{topo} switch+wire"), &pts);
        let io: Vec<(f64, f64)> =
            rows.iter().filter(|r| r.topo == topo).map(|r| (r.tiles as f64, r.io_pct)).collect();
        plot.series(&format!("{topo} io"), &io);
    }
    format!("{}\n{}", t.render(), plot.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clos_interconnect_exceeds_mesh() {
        // §5.1.2: Clos interconnect ~5-8% vs mesh 2-3% on economical
        // dies; at minimum Clos > mesh everywhere at >=64 tiles.
        let rows = generate(&ChipTech::default()).unwrap();
        for &tiles in super::super::fig5::TILE_POINTS {
            if tiles < 64 {
                continue;
            }
            let c = rows.iter().find(|r| r.topo == "clos" && r.tiles == tiles).unwrap();
            let m = rows.iter().find(|r| r.topo == "mesh" && r.tiles == tiles).unwrap();
            let ci = c.switch_pct + c.wire_pct;
            let mi = m.switch_pct + m.wire_pct;
            assert!(ci > mi, "tiles={tiles}: clos {ci} <= mesh {mi}");
        }
    }

    #[test]
    fn clos_io_share_substantial() {
        // I/O dominates small-memory Clos chips; at 256 KB it is still
        // a double-digit share at 256 tiles (paper Fig 6).
        let rows = generate(&ChipTech::default()).unwrap();
        let c256 = rows.iter().find(|r| r.topo == "clos" && r.tiles == 256).unwrap();
        assert!(c256.io_pct > 10.0, "io {}%", c256.io_pct);
        // Mesh I/O share shrinks with tiles.
        let m64 = rows.iter().find(|r| r.topo == "mesh" && r.tiles == 64).unwrap();
        let m1024 = rows.iter().find(|r| r.topo == "mesh" && r.tiles == 1024).unwrap();
        assert!(m1024.io_pct < m64.io_pct);
    }
}
