//! Faults figure (extension; not in the paper): availability and tail
//! inflation vs fault fraction — the degradation story of the emulated
//! memory when tiles die, links degrade or flake, and switch ports fail.
//!
//! For each system ([`SYSTEMS`], the 1,024- and 4,096-tile Clos points
//! at `k = tiles - tiles/8` so the dead-tile budget fits) and each fault
//! fraction in [`FRACS_PM`] (per mille, 0–10 %), the figure replays the
//! whole [`crate::workload::trace`] pattern catalogue through the
//! contention lab under a seed-deterministic [`FaultPlan`]
//! ([`FaultPlan::fraction`]: dead tiles + degraded links + flaky links
//! at the fraction, ports failed at half of it) and reports the
//! slowdown and p99 tail inflation against the fraction-0 baseline of
//! the same grid, alongside the DES's retry/timeout counters and the
//! materialised fault census.
//!
//! Two determinism properties make the ratios meaningful and the figure
//! golden-pinnable:
//!
//! * the *workload* seed of a cell is the contention lab's
//!   ([`contention::cell_seed`]) and does NOT fold the fault fraction —
//!   every fraction replays the identical traces, so slowdown is a pure
//!   fault effect;
//! * the *plan* seed ([`plan_for`]) folds the sweep seed, the design
//!   point and the fraction, and materialisation draws from canonical
//!   [`point_seed`] streams — any `--jobs` count is bit-identical.
//!
//! The fraction-0 column is the healthy contention lab bit for bit (the
//! empty-plan oracle rule; proven in the tests below and in
//! `tests/fault_determinism.rs`).

use std::collections::HashMap;

use anyhow::{Context, Result};

use super::{contention, topo_str, FigOpts};
use crate::api::{DesignPoint, Report, Row};
use crate::coordinator::{point_seed, ParallelSweep, SweepPoint};
use crate::emulation::{EmulationSetup, TopologyKind};
use crate::fault::FaultPlan;
use crate::sim::contention::ContentionStats;
use crate::util::plot::Plot;
use crate::util::table::{f, Table};
use crate::workload::trace::TracePattern;

/// Systems plotted (Clos points, like the contention figure).
pub const SYSTEMS: &[usize] = &[1024, 4096];

/// Tile memory used.
pub const MEM_KB: u32 = 128;

/// Concurrent clients per cell.
pub const CLIENTS: usize = 8;

/// Access budget per client per cell.
pub const ACCESSES: usize = 300;

/// Fault fractions swept, in per mille (0, 2 %, 5 %, 10 %). The 0 row
/// is the healthy baseline every ratio is computed against.
pub const FRACS_PM: &[u32] = &[0, 20, 50, 100];

/// The emulation size the figure uses: 7/8 of the tiles. Full emulation
/// (`k = tiles - 1`) has zero slack — ANY dead tile is a capacity
/// error — so the figure leaves `tiles/8` spare tiles, enough for the
/// 10 % dead-tile point with head room.
pub fn emulation_k(tiles: usize) -> usize {
    tiles - tiles / 8
}

/// The seed-deterministic plan of one (point, fraction) column: a
/// [`FaultPlan::fraction`] plan whose seed is a pure function of the
/// sweep seed, the design point and the fraction — never of scheduling.
/// Fraction 0 is the empty plan.
pub fn plan_for(point: &SweepPoint, frac_pm: u32, sweep_seed: u64) -> FaultPlan {
    FaultPlan::fraction(
        frac_pm as f64 / 1000.0,
        point_seed(
            sweep_seed,
            0xFA17_5EED ^ point.canonical_key() ^ ((frac_pm as u64) << 32),
        ),
    )
}

/// One grid cell: a design point replaying one pattern under one fault
/// fraction. The unit the sweep engine maps over.
#[derive(Clone, Copy, Debug)]
pub struct Cell {
    /// The design point.
    pub point: SweepPoint,
    /// Fault fraction, per mille (0 = healthy baseline).
    pub frac_pm: u32,
    /// Access pattern every client replays.
    pub pattern: TracePattern,
    /// Concurrent clients.
    pub clients: usize,
    /// Accesses per client.
    pub accesses: usize,
}

impl Cell {
    /// The underlying contention-lab cell. Its seed deliberately
    /// ignores `frac_pm`: every fraction replays the identical
    /// workload, so the figure's ratios isolate the fault effect.
    pub fn inner(&self) -> contention::Cell {
        contention::Cell {
            point: self.point,
            pattern: self.pattern,
            clients: self.clients,
            accesses: self.accesses,
        }
    }
}

/// One evaluated cell: the scenario summary plus the materialised fault
/// census and the ratios against the fraction-0 baseline.
#[derive(Clone, Debug)]
pub struct CellResult {
    /// The design point.
    pub point: SweepPoint,
    /// Fault fraction, per mille.
    pub frac_pm: u32,
    /// Pattern label.
    pub pattern: String,
    /// Concurrent clients.
    pub clients: usize,
    /// Tiles the materialised plan killed.
    pub dead_tiles: usize,
    /// Undirected links degraded (jitter).
    pub degraded_links: usize,
    /// Undirected links flaky (drop + retry).
    pub flaky_links: usize,
    /// Undirected links fully down (after healing).
    pub failed_links: usize,
    /// Sampled failures restored by the connectivity heal rule.
    pub healed_links: usize,
    /// Everything the scenario measured (includes retries/timeouts).
    pub stats: ContentionStats,
    /// Mean latency over the fraction-0 mean of the same
    /// (system, pattern, clients) cell. Exactly 1.0 on baseline rows.
    pub slowdown: f64,
    /// p99 latency over the fraction-0 p99 — the tail-inflation axis.
    pub p99_inflation: f64,
}

impl CellResult {
    /// Report/row name: `clos-1024-f50-zipf-c8`.
    pub fn name(&self) -> String {
        format!(
            "{}-{}-f{}-{}-c{}",
            topo_str(self.point.kind),
            self.point.tiles,
            self.frac_pm,
            self.pattern,
            self.clients
        )
    }
}

/// Fill in the baseline ratios: each row is divided by the fraction-0
/// row of the same (system, pattern, clients) cell. Rows without a
/// baseline in the set keep ratio 1.0.
pub fn annotate(mut rows: Vec<CellResult>) -> Vec<CellResult> {
    let mut base: HashMap<(usize, String, usize), (f64, f64)> = HashMap::new();
    for r in &rows {
        if r.frac_pm == 0 {
            base.insert(
                (r.point.tiles, r.pattern.clone(), r.clients),
                (r.stats.latency.mean(), r.stats.dist.p99),
            );
        }
    }
    for r in &mut rows {
        if let Some(&(mean0, p990)) = base.get(&(r.point.tiles, r.pattern.clone(), r.clients)) {
            if mean0 > 0.0 {
                r.slowdown = r.stats.latency.mean() / mean0;
            }
            if p990 > 0.0 {
                r.p99_inflation = r.stats.dist.p99 / p990;
            }
        }
    }
    rows
}

/// Evaluate a cell grid on the sweep engine: one setup is built per
/// unique (design point, fraction) column — fraction 0 through the
/// plain builder path, faulted columns through
/// [`DesignPoint::faults`] — then the cells fan out across the worker
/// pool (one DES timeline each) and come back annotated, in input
/// order, bit-identical at any job count.
pub fn eval_cells(engine: &ParallelSweep, cells: &[Cell]) -> Result<Vec<CellResult>> {
    let mut setups: HashMap<(u64, u32), EmulationSetup> = HashMap::new();
    for cell in cells {
        let key = (cell.point.canonical_key(), cell.frac_pm);
        if !setups.contains_key(&key) {
            let p = cell.point;
            let mut dp =
                DesignPoint::new(p.kind, p.tiles).mem_kb(p.mem_kb).k(p.k).tech(engine.tech());
            let plan = plan_for(&p, cell.frac_pm, engine.seed());
            if !plan.is_empty() {
                dp = dp.faults(plan);
            }
            let setup = dp.build().with_context(|| {
                format!("building faults cell point {p:?} at {} per mille", cell.frac_pm)
            })?;
            setups.insert(key, setup);
        }
    }
    let rows = engine.map(cells, |cell| {
        let setup = setups
            .get(&(cell.point.canonical_key(), cell.frac_pm))
            .context("cell point missing from the setup table")?;
        let inner = cell.inner();
        let stats = contention::eval_cell(setup, &inner, contention::cell_seed(engine.seed(), &inner))?;
        let (dead, degraded, flaky, failed, healed) = match &setup.fault {
            Some(f) => (
                f.map.dead_tiles.len(),
                f.map.degraded_links,
                f.map.flaky_links,
                f.map.failed_links,
                f.map.healed_links,
            ),
            None => (0, 0, 0, 0, 0),
        };
        Ok(CellResult {
            point: cell.point,
            frac_pm: cell.frac_pm,
            pattern: cell.pattern.label().to_string(),
            clients: cell.clients,
            dead_tiles: dead,
            degraded_links: degraded,
            flaky_links: flaky,
            failed_links: failed,
            healed_links: healed,
            stats,
            slowdown: 1.0,
            p99_inflation: 1.0,
        })
    })?;
    Ok(annotate(rows))
}

/// The figure's dataset.
#[derive(Clone, Debug)]
pub struct FigFaults {
    /// One row per (system, fraction, pattern) cell, in grid order.
    pub rows: Vec<CellResult>,
}

/// The figure's cell grid, in generation order: fraction-major inside
/// each system so the healthy baselines of a system evaluate first.
pub fn grid_cells() -> Vec<Cell> {
    let mut cells = Vec::new();
    for &system in SYSTEMS {
        let point = SweepPoint {
            kind: TopologyKind::Clos,
            tiles: system,
            mem_kb: MEM_KB,
            k: emulation_k(system),
        };
        for &frac_pm in FRACS_PM {
            for pattern in contention::patterns(contention::block_words(&point)) {
                cells.push(Cell { point, frac_pm, pattern, clients: CLIENTS, accesses: ACCESSES });
            }
        }
    }
    cells
}

/// Generate the faults dataset on a shared sweep engine.
pub fn generate_with(engine: &ParallelSweep) -> Result<FigFaults> {
    Ok(FigFaults { rows: eval_cells(engine, &grid_cells())? })
}

/// Generate the dataset (standalone: a fresh engine).
pub fn generate(opts: &FigOpts) -> Result<FigFaults> {
    generate_with(&opts.engine())
}

/// One report row for a cell — the schema `memclos faults --json` and
/// the figure share (documented in [`crate::api::report`]).
pub fn row_for(r: &CellResult) -> Row {
    let s = &r.stats;
    Row::new(&r.name())
        .int("system", r.point.tiles as u64)
        .int("k", r.point.k as u64)
        .int("fault_pm", r.frac_pm as u64)
        .str("pattern", &r.pattern)
        .int("clients", r.clients as u64)
        .int("accesses", s.accesses as u64)
        .int("dead_tiles", r.dead_tiles as u64)
        .int("degraded_links", r.degraded_links as u64)
        .int("flaky_links", r.flaky_links as u64)
        .int("failed_links", r.failed_links as u64)
        .int("healed_links", r.healed_links as u64)
        .num("mean_cycles", s.latency.mean())
        .num("p50", s.dist.p50)
        .num("p95", s.dist.p95)
        .num("p99", s.dist.p99)
        .num("max_cycles", s.dist.max)
        .num("slowdown", r.slowdown)
        .num("p99_inflation", r.p99_inflation)
        .int("retries", s.retries)
        .int("timeouts", s.timeouts)
        .num("wait_mean_cycles", s.wait.mean())
        .int("makespan_cycles", s.makespan)
}

/// Render a cell set as the machine-diffable faults report (the
/// document the golden harness pins as `faults.json`).
pub fn report_rows(rows: &[CellResult]) -> Report {
    let mut rep = Report::new("faults");
    for r in rows {
        rep.push(row_for(r));
    }
    rep
}

/// Full numeric output for the golden harness.
pub fn report(fig: &FigFaults) -> Report {
    report_rows(&fig.rows)
}

/// Render the dataset as a table plus one slowdown-vs-fault-fraction
/// plot per system.
pub fn render(fig: &FigFaults) -> String {
    let mut out = String::new();
    let mut t = Table::new(&[
        "system", "fault", "pattern", "dead", "down", "mean cy", "p99", "slowdown",
        "p99 infl", "retries", "timeouts",
    ])
    .with_title("Fault injection: slowdown and p99 tail inflation vs fault fraction");
    for r in &fig.rows {
        let s = &r.stats;
        t.row(&[
            r.point.tiles.to_string(),
            format!("{:.1}%", r.frac_pm as f64 / 10.0),
            r.pattern.clone(),
            r.dead_tiles.to_string(),
            r.failed_links.to_string(),
            f(s.latency.mean(), 1),
            f(s.dist.p99, 1),
            f(r.slowdown, 3),
            f(r.p99_inflation, 3),
            s.retries.to_string(),
            s.timeouts.to_string(),
        ]);
    }
    out.push_str(&t.render());
    for &system in SYSTEMS {
        let mut plot = Plot::new(
            &format!("Faults ({system}-tile Clos): slowdown vs fault fraction (%)"),
            "fault %",
            "slowdown",
        );
        let mut labels: Vec<&str> = Vec::new();
        for r in &fig.rows {
            if r.point.tiles == system && !labels.contains(&r.pattern.as_str()) {
                labels.push(r.pattern.as_str());
            }
        }
        for label in labels {
            let pts: Vec<(f64, f64)> = fig
                .rows
                .iter()
                .filter(|r| r.point.tiles == system && r.pattern == label)
                .map(|r| (r.frac_pm as f64 / 10.0, r.slowdown))
                .collect();
            plot.series(label, &pts);
        }
        out.push('\n');
        out.push_str(&plot.render());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Mode, Tech};
    use crate::sim::network::run_contention;

    /// A grid the tests can afford: one 256-tile point at k = 224
    /// (slack for the 10 % dead-tile column), two patterns.
    fn small_cells(fracs: &[u32]) -> Vec<Cell> {
        let point = SweepPoint {
            kind: TopologyKind::Clos,
            tiles: 256,
            mem_kb: 128,
            k: emulation_k(256),
        };
        let mut cells = Vec::new();
        for &frac_pm in fracs {
            for pattern in [TracePattern::Uniform, TracePattern::Zipf { theta: 1.2 }] {
                cells.push(Cell { point, frac_pm, pattern, clients: 8, accesses: 200 });
            }
        }
        cells
    }

    #[test]
    fn grid_covers_systems_fractions_and_patterns() {
        let cells = grid_cells();
        assert_eq!(cells.len(), SYSTEMS.len() * FRACS_PM.len() * 5);
        // Every (system, pattern) column has its fraction-0 baseline.
        for &system in SYSTEMS {
            for c in cells.iter().filter(|c| c.point.tiles == system) {
                assert!(cells.iter().any(|b| {
                    b.frac_pm == 0
                        && b.point.tiles == system
                        && b.pattern.label() == c.pattern.label()
                        && b.clients == c.clients
                }));
            }
        }
        // Plan seeds are canonical: same coordinates -> same plan;
        // fraction 0 -> the empty plan; any differing coordinate -> a
        // different plan.
        let p1024 = cells[0].point;
        assert_eq!(plan_for(&p1024, 50, 1), plan_for(&p1024, 50, 1));
        assert!(plan_for(&p1024, 0, 1).is_empty());
        assert_ne!(plan_for(&p1024, 20, 1), plan_for(&p1024, 50, 1));
        assert_ne!(plan_for(&p1024, 50, 1), plan_for(&p1024, 50, 2));
        let p4096 = cells.last().unwrap().point;
        assert_ne!(plan_for(&p1024, 50, 1), plan_for(&p4096, 50, 1));
    }

    #[test]
    fn zero_fraction_cells_are_the_healthy_oracle_bitwise() {
        // The empty-plan oracle rule at figure level: the fraction-0
        // column embeds the healthy contention lab (and, for uniform,
        // the legacy run_contention experiment) bit for bit.
        let engine = ParallelSweep::new(Mode::Exact, &Tech::default(), 2, 0xC105);
        let cells = small_cells(&[0]);
        let rows = eval_cells(&engine, &cells).unwrap();
        let point = cells[0].point;
        let setup = DesignPoint::new(point.kind, point.tiles)
            .mem_kb(point.mem_kb)
            .k(point.k)
            .build()
            .unwrap();
        let uni_cell = cells
            .iter()
            .find(|c| matches!(c.pattern, TracePattern::Uniform))
            .unwrap();
        let uni = rows.iter().find(|r| r.pattern == "uniform").unwrap();
        let legacy = run_contention(
            &setup,
            uni_cell.clients,
            uni_cell.accesses,
            contention::cell_seed(0xC105, &uni_cell.inner()),
        );
        assert_eq!(uni.stats.latency.count(), legacy.latency.count());
        assert_eq!(
            uni.stats.latency.mean().to_bits(),
            legacy.latency.mean().to_bits(),
            "fraction-0 uniform cell diverged from run_contention"
        );
        assert_eq!(uni.stats.inflation.to_bits(), legacy.inflation.to_bits());
        for r in &rows {
            assert_eq!(r.slowdown.to_bits(), 1f64.to_bits());
            assert_eq!(r.p99_inflation.to_bits(), 1f64.to_bits());
            assert_eq!(r.dead_tiles + r.degraded_links + r.flaky_links + r.failed_links, 0);
            assert_eq!(r.stats.retries, 0);
            assert_eq!(r.stats.timeouts, 0);
        }
    }

    #[test]
    fn faulted_cells_report_fault_work_and_sane_ratios() {
        let engine = ParallelSweep::new(Mode::Exact, &Tech::default(), 4, 0xC105);
        let rows = eval_cells(&engine, &small_cells(&[0, 100])).unwrap();
        let faulted: Vec<_> = rows.iter().filter(|r| r.frac_pm == 100).collect();
        assert!(!faulted.is_empty());
        for r in faulted {
            assert!(r.dead_tiles > 0, "{r:?}");
            assert!(r.degraded_links > 0 && r.flaky_links > 0, "{r:?}");
            // 10 % drop over thousands of flaky-hop traversals: the
            // retry counter must move.
            assert!(r.stats.retries > 0, "{r:?}");
            // Loose sanity on the ratios (the remap can shift the mean
            // slightly either way, but faults cannot make the system
            // an order of magnitude faster).
            assert!(r.slowdown > 0.9, "{r:?}");
            assert!(r.p99_inflation > 0.9, "{r:?}");
        }
    }

    #[test]
    fn cells_are_jobs_invariant() {
        let cells = small_cells(&[0, 50]);
        let seq =
            eval_cells(&ParallelSweep::new(Mode::Exact, &Tech::default(), 1, 3), &cells).unwrap();
        let par =
            eval_cells(&ParallelSweep::new(Mode::Exact, &Tech::default(), 8, 3), &cells).unwrap();
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.frac_pm, b.frac_pm);
            assert_eq!(a.pattern, b.pattern);
            assert_eq!(a.dead_tiles, b.dead_tiles);
            assert_eq!(a.stats.latency.mean().to_bits(), b.stats.latency.mean().to_bits());
            assert_eq!(a.stats.dist, b.stats.dist);
            assert_eq!(a.stats.retries, b.stats.retries);
            assert_eq!(a.stats.timeouts, b.stats.timeouts);
            assert_eq!(a.slowdown.to_bits(), b.slowdown.to_bits());
        }
    }

    #[test]
    fn report_rows_round_trip_their_fields() {
        let engine = ParallelSweep::new(Mode::Exact, &Tech::default(), 2, 7);
        let cells = small_cells(&[50]);
        let rows = eval_cells(&engine, &cells).unwrap();
        let rendered = report_rows(&rows).render();
        assert!(rendered.starts_with("{\"bench\": \"faults\", \"results\": ["));
        let r = &rows[0];
        let s = &r.stats;
        let field = |key: &str, want: String| {
            let needle = format!("\"{key}\": {want}");
            assert!(rendered.contains(&needle), "missing `{needle}` in {rendered}");
        };
        field("name", format!("\"{}\"", r.name()));
        field("fault_pm", "50".to_string());
        field("dead_tiles", r.dead_tiles.to_string());
        field("degraded_links", r.degraded_links.to_string());
        field("flaky_links", r.flaky_links.to_string());
        field("failed_links", r.failed_links.to_string());
        field("mean_cycles", format!("{:.4}", s.latency.mean()));
        field("p99", format!("{:.4}", s.dist.p99));
        field("slowdown", format!("{:.4}", r.slowdown));
        field("p99_inflation", format!("{:.4}", r.p99_inflation));
        field("retries", s.retries.to_string());
        field("timeouts", s.timeouts.to_string());
    }
}
