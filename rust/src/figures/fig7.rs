//! Fig 7: total interposer area for multi-chip configurations.

use anyhow::Result;

use crate::tech::{ChipTech, InterposerTech};
use crate::topology::{ClosSpec, MeshSpec};
use crate::util::plot::Plot;
use crate::util::table::{f, Table};
use crate::vlsi::{ClosFloorplan, InterposerPlan, MeshFloorplan};

/// One data point.
#[derive(Clone, Copy, Debug)]
pub struct Row {
    /// "clos" or "mesh".
    pub topo: &'static str,
    /// Chips on the interposer.
    pub chips: usize,
    /// Tile memory, KB.
    pub mem_kb: u32,
    /// System tiles (chips x 256).
    pub tiles: usize,
    /// Interposer area, mm^2.
    pub interposer_mm2: f64,
    /// Wiring-channel share (Clos only).
    pub channel_pct: f64,
    /// Min..max inter-chip wire delay, ns.
    pub wire_delay_ns: (f64, f64),
}

/// Chip counts plotted.
pub const CHIP_POINTS: &[usize] = &[2, 4, 8, 16];

/// Generate the Fig 7 dataset.
pub fn generate(chip_tech: &ChipTech, ip_tech: &InterposerTech) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for &mem in &[64u32, 128] {
        for &chips in CHIP_POINTS {
            let tiles = chips * 256;
            let cspec = ClosSpec::with_tiles(tiles);
            let cfp = ClosFloorplan::plan(&cspec, mem, chip_tech)?;
            let cip = InterposerPlan::clos(chips, &cfp, ip_tech)?;
            rows.push(Row {
                topo: "clos",
                chips,
                mem_kb: mem,
                tiles,
                interposer_mm2: cip.area_mm2,
                channel_pct: 100.0 * cip.channel_fraction(),
                wire_delay_ns: (cip.wire_delay_min_ns, cip.wire_delay_max_ns),
            });
            // Mesh systems must form square chip grids.
            if (chips as f64).sqrt().fract() == 0.0 {
                let mspec = MeshSpec::with_tiles(tiles);
                let mfp = MeshFloorplan::plan(&mspec, mem, chip_tech)?;
                let mip = InterposerPlan::mesh(chips, &mfp, ip_tech)?;
                rows.push(Row {
                    topo: "mesh",
                    chips,
                    mem_kb: mem,
                    tiles,
                    interposer_mm2: mip.area_mm2,
                    channel_pct: 0.0,
                    wire_delay_ns: (mip.wire_delay_min_ns, mip.wire_delay_max_ns),
                });
            }
        }
    }
    Ok(rows)
}

/// Render the dataset.
pub fn render(rows: &[Row]) -> String {
    let mut t = Table::new(&[
        "topo",
        "chips",
        "mem KB",
        "tiles",
        "interposer mm^2",
        "channel %",
        "wire delay ns",
    ])
    .with_title("Fig 7: interposer area for multi-chip systems");
    for r in rows {
        t.row(&[
            r.topo.to_string(),
            r.chips.to_string(),
            r.mem_kb.to_string(),
            r.tiles.to_string(),
            f(r.interposer_mm2, 0),
            f(r.channel_pct, 1),
            format!("{}-{}", f(r.wire_delay_ns.0, 2), f(r.wire_delay_ns.1, 2)),
        ]);
    }
    let mut plot = Plot::new("Fig 7: interposer area (mm^2) vs chips (log2)", "chips", "mm^2");
    for &mem in &[64u32, 128] {
        for topo in ["clos", "mesh"] {
            let pts: Vec<(f64, f64)> = rows
                .iter()
                .filter(|r| r.topo == topo && r.mem_kb == mem)
                .map(|r| (r.chips as f64, r.interposer_mm2))
                .collect();
            if !pts.is_empty() {
                plot.series(&format!("{topo}-{mem}KB"), &pts);
            }
        }
    }
    format!("{}\n{}", t.render(), plot.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn area_grows_with_chips_and_channel_share_rises() {
        let rows = generate(&ChipTech::default(), &InterposerTech::default()).unwrap();
        let clos128: Vec<&Row> =
            rows.iter().filter(|r| r.topo == "clos" && r.mem_kb == 128).collect();
        for w in clos128.windows(2) {
            assert!(w[1].interposer_mm2 > w[0].interposer_mm2);
            assert!(w[1].channel_pct >= w[0].channel_pct - 1.0);
        }
        // §5.1.3: Clos inter-chip delay roughly 1-8 ns; mesh ~0.09 ns.
        for r in &rows {
            match r.topo {
                "clos" => {
                    assert!(r.wire_delay_ns.0 > 0.2 && r.wire_delay_ns.1 < 14.0, "{r:?}")
                }
                _ => assert!((r.wire_delay_ns.1 - 0.089).abs() < 0.02, "{r:?}"),
            }
        }
    }
}
