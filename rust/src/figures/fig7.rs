//! Fig 7: total interposer area for multi-chip configurations.

use anyhow::Result;

use crate::api::{Mode, Report, Tech};
use crate::coordinator::ParallelSweep;
use crate::tech::{ChipTech, InterposerTech};
use crate::topology::{ClosSpec, MeshSpec};
use crate::util::plot::Plot;
use crate::util::table::{f, Table};
use crate::vlsi::{ClosFloorplan, InterposerPlan, MeshFloorplan};

/// One data point.
#[derive(Clone, Copy, Debug)]
pub struct Row {
    /// "clos" or "mesh".
    pub topo: &'static str,
    /// Chips on the interposer.
    pub chips: usize,
    /// Tile memory, KB.
    pub mem_kb: u32,
    /// System tiles (chips x 256).
    pub tiles: usize,
    /// Interposer area, mm^2.
    pub interposer_mm2: f64,
    /// Wiring-channel share (Clos only).
    pub channel_pct: f64,
    /// Min..max inter-chip wire delay, ns.
    pub wire_delay_ns: (f64, f64),
}

/// Chip counts plotted.
pub const CHIP_POINTS: &[usize] = &[2, 4, 8, 16];

/// Generate the Fig 7 dataset on a shared sweep engine: interposer
/// plans fan out across the worker pool, reassembled in the figure's
/// render order (pure floorplan arithmetic, so any `--jobs` count is
/// bit-identical).
pub fn generate_with(engine: &ParallelSweep) -> Result<Vec<Row>> {
    let chip_tech = &engine.tech().chip;
    let ip_tech = &engine.tech().ip;
    let mut items: Vec<(u32, usize)> = Vec::new();
    for &mem in &[64u32, 128] {
        for &chips in CHIP_POINTS {
            items.push((mem, chips));
        }
    }
    let nested = engine.map(&items, |&(mem, chips)| {
        let tiles = chips * 256;
        let mut rows = Vec::with_capacity(2);
        let cspec = ClosSpec::with_tiles(tiles);
        let cfp = ClosFloorplan::plan(&cspec, mem, chip_tech)?;
        let cip = InterposerPlan::clos(chips, &cfp, ip_tech)?;
        rows.push(Row {
            topo: "clos",
            chips,
            mem_kb: mem,
            tiles,
            interposer_mm2: cip.area_mm2,
            channel_pct: 100.0 * cip.channel_fraction(),
            wire_delay_ns: (cip.wire_delay_min_ns, cip.wire_delay_max_ns),
        });
        // Mesh systems must form square chip grids.
        if (chips as f64).sqrt().fract() == 0.0 {
            let mspec = MeshSpec::with_tiles(tiles);
            let mfp = MeshFloorplan::plan(&mspec, mem, chip_tech)?;
            let mip = InterposerPlan::mesh(chips, &mfp, ip_tech)?;
            rows.push(Row {
                topo: "mesh",
                chips,
                mem_kb: mem,
                tiles,
                interposer_mm2: mip.area_mm2,
                channel_pct: 0.0,
                wire_delay_ns: (mip.wire_delay_min_ns, mip.wire_delay_max_ns),
            });
        }
        Ok(rows)
    })?;
    Ok(nested.into_iter().flatten().collect())
}

/// Generate the Fig 7 dataset (standalone: a fresh engine).
pub fn generate(chip_tech: &ChipTech, ip_tech: &InterposerTech) -> Result<Vec<Row>> {
    let tech = Tech { chip: chip_tech.clone(), ip: ip_tech.clone(), ..Tech::default() };
    generate_with(&ParallelSweep::with_defaults(Mode::Exact, &tech))
}

/// Full numeric output for the golden harness.
pub fn report(rows: &[Row]) -> Report {
    let mut rep = Report::new("fig7");
    for r in rows {
        rep.push(
            crate::api::Row::new(&format!("{}-{}chips-{}KB", r.topo, r.chips, r.mem_kb))
                .int("chips", r.chips as u64)
                .int("mem_kb", r.mem_kb as u64)
                .int("tiles", r.tiles as u64)
                .num("interposer_mm2", r.interposer_mm2)
                .num("channel_pct", r.channel_pct)
                .num("wire_delay_min_ns", r.wire_delay_ns.0)
                .num("wire_delay_max_ns", r.wire_delay_ns.1),
        );
    }
    rep
}

/// Render the dataset.
pub fn render(rows: &[Row]) -> String {
    let mut t = Table::new(&[
        "topo",
        "chips",
        "mem KB",
        "tiles",
        "interposer mm^2",
        "channel %",
        "wire delay ns",
    ])
    .with_title("Fig 7: interposer area for multi-chip systems");
    for r in rows {
        t.row(&[
            r.topo.to_string(),
            r.chips.to_string(),
            r.mem_kb.to_string(),
            r.tiles.to_string(),
            f(r.interposer_mm2, 0),
            f(r.channel_pct, 1),
            format!("{}-{}", f(r.wire_delay_ns.0, 2), f(r.wire_delay_ns.1, 2)),
        ]);
    }
    let mut plot = Plot::new("Fig 7: interposer area (mm^2) vs chips (log2)", "chips", "mm^2");
    for &mem in &[64u32, 128] {
        for topo in ["clos", "mesh"] {
            let pts: Vec<(f64, f64)> = rows
                .iter()
                .filter(|r| r.topo == topo && r.mem_kb == mem)
                .map(|r| (r.chips as f64, r.interposer_mm2))
                .collect();
            if !pts.is_empty() {
                plot.series(&format!("{topo}-{mem}KB"), &pts);
            }
        }
    }
    format!("{}\n{}", t.render(), plot.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn area_grows_with_chips_and_channel_share_rises() {
        let rows = generate(&ChipTech::default(), &InterposerTech::default()).unwrap();
        let clos128: Vec<&Row> =
            rows.iter().filter(|r| r.topo == "clos" && r.mem_kb == 128).collect();
        for w in clos128.windows(2) {
            assert!(w[1].interposer_mm2 > w[0].interposer_mm2);
            assert!(w[1].channel_pct >= w[0].channel_pct - 1.0);
        }
        // §5.1.3: Clos inter-chip delay roughly 1-8 ns; mesh ~0.09 ns.
        for r in &rows {
            match r.topo {
                "clos" => {
                    assert!(r.wire_delay_ns.0 > 0.2 && r.wire_delay_ns.1 < 14.0, "{r:?}")
                }
                _ => assert!((r.wire_delay_ns.1 - 0.089).abs() < 0.02, "{r:?}"),
            }
        }
    }
}
