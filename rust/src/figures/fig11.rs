//! Fig 11: emulation slowdown over a range of instruction mixes —
//! global accesses 0–50%, local fixed at 20% — for 1,024- and
//! 4,096-tile systems (full-size emulations).
//!
//! When the `mix_sweep` artifact is available the slowdown surface is
//! evaluated by the AOT-compiled L2 model; the native formula is the
//! fallback and oracle.

use anyhow::Result;

use super::fig9::MEM_KB;
use super::{topo_str, FigOpts};
use crate::api::Report;
use crate::coordinator::{ParallelSweep, SweepPoint};
use crate::emulation::{SequentialMachine, TopologyKind};
use crate::runtime::ArtifactSet;
use crate::util::plot::Plot;
use crate::util::table::{f, Table};
use crate::workload::mixes::fig11_grid;
use crate::workload::predict_slowdown;

/// One data point.
#[derive(Clone, Copy, Debug)]
pub struct Row {
    /// System tiles.
    pub system: usize,
    /// "clos" or "mesh".
    pub topo: &'static str,
    /// Global-access fraction.
    pub global_frac: f64,
    /// Slowdown vs the sequential machine.
    pub slowdown: f64,
}

/// Mix points on the 0..=50% global axis.
pub const GRID: usize = 21;

/// The figure's latency points: the full emulation of every
/// (system, topology) — a subset of fig 9's sweep, so a shared engine
/// serves them from the result cache.
pub fn sweep_points() -> Vec<SweepPoint> {
    let mut points = Vec::new();
    for &system in super::fig9::SYSTEMS {
        for kind in [TopologyKind::Clos, TopologyKind::Mesh] {
            points.push(SweepPoint { kind, tiles: system, mem_kb: MEM_KB, k: system - 1 });
        }
    }
    points
}

/// Generate the Fig 11 dataset on a shared sweep engine.
pub fn generate_with(engine: &ParallelSweep) -> Result<Vec<Row>> {
    let results = engine.eval_points(&sweep_points())?;
    let dram = SequentialMachine::with_measured_dram(1).dram_ns;
    let grid = fig11_grid(GRID);

    // Prefer the AOT mix-sweep artifact (exercises the L2 model) — but
    // only for sampling modes. `Mode::Exact` means the fully analytic
    // path end to end, artifact or no artifact, which is what keeps the
    // golden snapshots environment-independent (the harness and
    // `figures --all` default to Exact; a machine with `artifacts/`
    // installed must produce the same bits as artifact-less CI).
    let xla_surface = match engine.mode() {
        crate::api::Mode::Exact => None,
        _ => mix_sweep_artifact(),
    };

    let mut rows = Vec::new();
    for r in &results {
        let topo = topo_str(r.point.kind);
        let slowdowns: Vec<f64> = match &xla_surface {
            Some(art) => {
                eval_mix_sweep(art, &grid, r.mean_cycles, dram).unwrap_or_else(|_| {
                    grid.iter().map(|m| predict_slowdown(m, r.mean_cycles, dram)).collect()
                })
            }
            None => grid.iter().map(|m| predict_slowdown(m, r.mean_cycles, dram)).collect(),
        };
        for (m, s) in grid.iter().zip(slowdowns) {
            rows.push(Row {
                system: r.point.tiles,
                topo,
                global_frac: m.global,
                slowdown: s,
            });
        }
    }
    rows.sort_by(|a, b| {
        (a.system, a.topo, a.global_frac)
            .partial_cmp(&(b.system, b.topo, b.global_frac))
            .unwrap()
    });
    Ok(rows)
}

/// Generate the Fig 11 dataset (standalone: a fresh engine).
pub fn generate(opts: &FigOpts) -> Result<Vec<Row>> {
    generate_with(&opts.engine())
}

/// Full numeric output for the golden harness.
pub fn report(rows: &[Row]) -> Report {
    let mut rep = Report::new("fig11");
    for r in rows {
        rep.push(
            crate::api::Row::new(&format!(
                "{}-{}t-{}pct",
                r.topo,
                r.system,
                f(r.global_frac * 100.0, 1)
            ))
            .int("system", r.system as u64)
            .num("global_frac", r.global_frac)
            .num("slowdown", r.slowdown),
        );
    }
    rep
}

fn mix_sweep_artifact() -> Option<crate::runtime::Artifact> {
    let set = ArtifactSet::new().ok()?;
    if set.available("mix_sweep_256") {
        set.load("mix_sweep_256").ok()
    } else {
        None
    }
}

/// Evaluate the slowdown surface on the AOT L2 artifact (padded to its
/// fixed 256-point shape).
fn eval_mix_sweep(
    art: &crate::runtime::Artifact,
    grid: &[crate::workload::InstructionMix],
    emu_latency: f64,
    dram_latency: f64,
) -> Result<Vec<f64>> {
    const M: usize = 256;
    let mut g = vec![0f32; M];
    let mut l = vec![0f32; M];
    for (i, m) in grid.iter().enumerate() {
        g[i] = m.global as f32;
        l[i] = m.local as f32;
    }
    let lat_emu = vec![emu_latency as f32; M];
    let lat_seq = vec![dram_latency as f32];
    let outs = art.execute(&[
        xla::Literal::vec1(&g),
        xla::Literal::vec1(&l),
        xla::Literal::vec1(&lat_emu),
        xla::Literal::vec1(&lat_seq),
    ])?;
    let s = outs[0].to_vec::<f32>()?;
    Ok(s[..grid.len()].iter().map(|&x| x as f64).collect())
}

/// Render the dataset.
pub fn render(rows: &[Row]) -> String {
    let mut out = String::new();
    let mut t = Table::new(&["system", "topo", "global %", "slowdown"])
        .with_title("Fig 11: slowdown vs instruction mix (local fixed at 20%)");
    for r in rows {
        t.row(&[
            r.system.to_string(),
            r.topo.to_string(),
            f(r.global_frac * 100.0, 1),
            f(r.slowdown, 3),
        ]);
    }
    out.push_str(&t.render());
    for &system in super::fig9::SYSTEMS {
        let mut plot = Plot::new(
            &format!("Fig 11 ({system}-tile system): slowdown vs global fraction"),
            "global %",
            "slowdown",
        )
        .xscale(crate::util::plot::XScale::Linear);
        for topo in ["clos", "mesh"] {
            let pts: Vec<(f64, f64)> = rows
                .iter()
                .filter(|r| r.system == system && r.topo == topo)
                .map(|r| (r.global_frac * 100.0, r.slowdown))
                .collect();
            plot.series(topo, &pts);
        }
        out.push('\n');
        out.push_str(&plot.render());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_shape_holds() {
        let rows = generate(&FigOpts::default()).unwrap();
        // zero globals -> parity for every system/topology
        for r in rows.iter().filter(|r| r.global_frac == 0.0) {
            assert!((r.slowdown - 1.0).abs() < 1e-9, "{r:?}");
        }
        // monotone in global fraction
        for &system in super::super::fig9::SYSTEMS {
            for topo in ["clos", "mesh"] {
                let series: Vec<&Row> = rows
                    .iter()
                    .filter(|r| r.system == system && r.topo == topo)
                    .collect();
                assert_eq!(series.len(), GRID);
                for w in series.windows(2) {
                    assert!(w[1].slowdown >= w[0].slowdown - 1e-9);
                }
                // §7.2: converges toward a worst case ~1.5-2.5 band as
                // the mix becomes global-dominated (the asymptote is
                // emu/dram latency; at 50% globals we are near it).
                let worst = series.last().unwrap().slowdown;
                assert!(worst > 1.5 && worst < 5.5, "{topo}@{system}: worst {worst}");
            }
        }
        // Dhrystone-like point (20% global) for 4096 clos sits in 2-3.
        let d = rows
            .iter()
            .find(|r| {
                r.system == 4096 && r.topo == "clos" && (r.global_frac - 0.2).abs() < 1e-9
            })
            .unwrap();
        assert!(d.slowdown > 1.8 && d.slowdown < 3.3, "{}", d.slowdown);
    }
}
