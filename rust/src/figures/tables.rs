//! Regenerate the paper's parameter tables (Tables 1–5).

use crate::netmodel::NetParams;
use crate::tech::{itrs, ChipTech, InterposerTech, MemTech};
use crate::util::table::{f, Table};

/// Table 1: processing-chip implementation parameters.
pub fn table1(tech: &ChipTech) -> Table {
    let mut t = Table::new(&["Parameter", "Value"]).with_title("Table 1: processing chip (28 nm)");
    t.row_strs(&["Process geometry", &format!("{} nm", tech.process_nm)]);
    t.row_strs(&["FO4 delay", &format!("{} ps", f(tech.fo4_ps, 1))]);
    t.row_strs(&[
        "Economical chip sizes",
        &format!("{}-{} mm^2", tech.econ_min_mm2, tech.econ_max_mm2),
    ]);
    t.row_strs(&["Metal layers", &tech.metal_layers.to_string()]);
    t.row_strs(&["Interconnect wire pitch", &format!("{} nm", tech.wire_pitch_nm)]);
    t.row_strs(&["Repeated wire delay", &format!("{} ps/mm", tech.wire_delay_ps_per_mm)]);
    t.row_strs(&["Processor area", &format!("{} mm^2", tech.processor_area_mm2)]);
    t.row_strs(&["Switch area", &format!("{} mm^2", tech.switch_area_mm2)]);
    t.row_strs(&[
        "I/O pad dimensions",
        &format!("{}x{} um", tech.io_pad_w_um, tech.io_pad_h_um),
    ]);
    t.row_strs(&["Wires per link", &tech.wires_per_link.to_string()]);
    t.row_strs(&[
        "Power and ground I/Os",
        &format!("{}%", (tech.power_ground_fraction * 100.0) as u32),
    ]);
    t.row_strs(&["Clock rate", &format!("{} GHz", tech.clock_ghz)]);
    t
}

/// Table 2: interposer implementation parameters.
pub fn table2(tech: &InterposerTech) -> Table {
    let mut t = Table::new(&["Parameter", "Value"]).with_title("Table 2: interposer (65 nm)");
    t.row_strs(&["Process geometry", &format!("{} nm", tech.process_nm)]);
    t.row_strs(&["FO4 delay", &format!("{} ps", f(tech.fo4_ps, 1))]);
    t.row_strs(&["Metal layers", &tech.metal_layers.to_string()]);
    t.row_strs(&[
        "Interconnect wire pitch",
        &format!("{} um ({}/mm half-shielded)", tech.wire_pitch_um, f(tech.shielded_wires_per_mm(), 0)),
    ]);
    t.row_strs(&["Repeated wire delay", &format!("{} ps/mm", tech.wire_delay_ps_per_mm)]);
    t.row_strs(&[
        "Microbump pitch",
        &format!("{} um ({} bumps/mm^2)", tech.microbump_pitch_um, f(tech.microbumps_per_mm2(), 2)),
    ]);
    t.row_strs(&["TSV pitch", &format!("{} um", tech.tsv_pitch_um)]);
    t.row_strs(&["C4 bump pitch", &format!("{} um", tech.c4_pitch_um)]);
    t.row_strs(&["Wires per link", &tech.wires_per_link.to_string()]);
    t
}

/// Table 3: ITRS global-wire data with the derived repeated-wire
/// delays.
pub fn table3() -> Table {
    let mut t = Table::new(&[
        "Geometry (nm)",
        "Min pitch (nm)",
        "RC (ps/mm)",
        "Edition",
        "tau (ps/mm)",
    ])
    .with_title("Table 3: ITRS global wires + derived repeated-wire delay");
    for row in itrs::TABLE3 {
        let tau = row
            .rc_ps_per_mm
            .map(|rc| f(itrs::repeated_wire_delay_ps_per_mm(itrs::fo4_ps(row.geometry_nm), rc), 0))
            .unwrap_or_else(|| "-".into());
        t.row(&[
            f(row.geometry_nm, 2),
            f(row.min_pitch_nm, 0),
            row.rc_ps_per_mm.map(|v| f(v, 0)).unwrap_or_else(|| "-".into()),
            row.edition.to_string(),
            tau,
        ]);
    }
    t
}

/// Table 4: memory-technology comparison.
pub fn table4() -> Table {
    let mut t = Table::new(&[
        "Type",
        "Capacity (MB)",
        "Area factor (F^2)",
        "Efficiency",
        "Process (nm)",
        "Density (KB/mm^2)",
        "Cycle (ns)",
    ])
    .with_title("Table 4: memory technologies (ITRS SYSD3b)");
    for m in MemTech::all() {
        let (lo, hi) = m.typical_capacity_mb();
        let cap = match (lo, hi) {
            (None, Some(h)) => format!("<{h}"),
            (Some(l), Some(h)) => format!("{l}-{h}"),
            (Some(l), None) => format!(">{l}"),
            _ => "-".into(),
        };
        t.row(&[
            m.name().to_string(),
            cap,
            f(m.cell_area_factor(), 0),
            format!("{}%", (m.area_efficiency() * 100.0) as u32),
            f(m.process_nm(), 0),
            f(m.density_kb_per_mm2(), 2),
            f(m.cycle_ns(), 1),
        ]);
    }
    t
}

/// Table 5: network performance-model parameters.
pub fn table5(p: &NetParams) -> Table {
    let mut t = Table::new(&["Parameter", "Value (cycles)"])
        .with_title("Table 5: network model parameters (XMP-64 fitted)");
    t.row_strs(&["Switch latency (t_switch)", &f(p.t_switch, 0)]);
    t.row_strs(&["Latency to open a route (t_open)", &f(p.t_open, 0)]);
    t.row_strs(&["Contention factor (c_cont)", &f(p.c_cont, 1)]);
    t.row_strs(&["Serialisation intra-chip", &f(p.t_serial_intra, 0)]);
    t.row_strs(&["Serialisation inter-chip", &f(p.t_serial_inter, 0)]);
    t.row_strs(&["Tile memory access (t_mem)", &f(p.t_mem, 0)]);
    t.row_strs(&["Tile link latency (t_tile)", "see floorplan (1-2)"]);
    t
}

/// All five tables as machine-diffable [`Report`](crate::api::Report)s
/// (the golden harness pins these alongside the figure reports).
pub fn reports(tech: &crate::api::Tech) -> Vec<crate::api::Report> {
    vec![
        table1(&tech.chip).to_report("table1"),
        table2(&tech.ip).to_report("table2"),
        table3().to_report("table3"),
        table4().to_report("table4"),
        table5(&tech.net).to_report("table5"),
    ]
}

/// All five tables rendered from a technology bundle (so
/// `--set`/`--config` overrides show up in the regenerated tables).
pub fn render_all(tech: &crate::api::Tech) -> String {
    [
        table1(&tech.chip).render(),
        table2(&tech.ip).render(),
        table3().render(),
        table4().render(),
        table5(&tech.net).render(),
    ]
    .join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render_nonempty() {
        let all = render_all(&crate::api::Tech::default());
        for needle in ["Table 1", "Table 2", "Table 3", "Table 4", "Table 5"] {
            assert!(all.contains(needle), "missing {needle}");
        }
        assert!(all.contains("155"), "chip wire delay");
        assert!(all.contains("778.51"), "SRAM density");
    }

    #[test]
    fn table3_derived_delays_near_quoted() {
        let t = table3();
        assert_eq!(t.len(), itrs::TABLE3.len());
        let rendered = t.render();
        // 26.76 nm row gives ~152-156 ps/mm; 68 nm row ~94 ps/mm.
        assert!(rendered.contains("1115"));
    }
}
