//! Interpreter throughput measurement — the decoded direct-threaded
//! loop vs the legacy enum-match loop on the corpus workload (the
//! repo's perf trajectory for whole-program emulation, not a paper
//! figure).
//!
//! | case | path |
//! |------|------|
//! | `decoded-emulated` | [`FastMachine`] over the predecoded corpus, emulated backend |
//! | `legacy-emulated`  | [`Machine`] over the raw corpus, emulated backend |
//! | `decoded-direct`   | [`FastMachine`], direct (DRAM) backend |
//! | `legacy-direct`    | [`Machine`], direct backend |
//! | `predecode-corpus` | decode-once cost for the whole corpus |
//!
//! [`assert_interp`] encodes the acceptance floor (decoded >= 5x the
//! legacy loop on the emulated corpus); [`Bench::write_json`] emits the
//! `BENCH_interp.json` schema (same family as `BENCH_hotpath.json`)
//! consumed by `rust/scripts/bench_hotpath.sh`.
//!
//! [`measure_jit`] is the third-tier companion (the `jit` bench group,
//! emitted as `BENCH_jit.json`): `jit-emulated` / `jit-direct` run the
//! [`JitCorpus`] native code, `legacy-emulated` rides along as the
//! in-file baseline, and `jit-compile-corpus` prices the compile-once
//! cost. [`assert_jit`] holds the JIT to >= 50x the legacy loop on the
//! emulated corpus. On hosts the compiler does not target,
//! [`measure_jit`] returns the typed
//! [`JitUnsupported`](crate::isa::JitUnsupported) error instead of a
//! number — callers fall back explicitly, never silently.

use anyhow::{Context, Result};

use crate::api::DesignPoint;
use crate::emulation::{EmulationSetup, SequentialMachine};
use crate::isa::decode::{predecode, FastMachine};
use crate::isa::interp::{DirectMemory, EmulatedChannelMemory, Machine};
use crate::isa::jit::{self, JitMachine};
use crate::util::bench::{black_box, fmt_duration, Bench};
use crate::workload::measured::{CompiledCorpus, JitCorpus};

/// Acceptance floor: decoded must beat legacy by this factor on the
/// emulated corpus.
pub const SPEEDUP_FLOOR: f64 = 5.0;

/// Acceptance floor for the third tier: the baseline JIT must beat the
/// legacy enum-match loop by this factor on the emulated corpus.
pub const JIT_SPEEDUP_FLOOR: f64 = 50.0;

/// Words of DRAM space per direct run (power of two: the fast loop's
/// address mask applies).
const DIRECT_SPACE: u64 = 1 << 20;

/// Tile-local words per run (the corpus needs a few hundred frame
/// slots; kept small so zeroing does not dominate the measurement).
const LOCAL_WORDS: usize = 4096;

/// The corpus workload plus everything the measurement reuses.
pub struct InterpWorkload {
    /// Compiled + predecoded corpus.
    pub corpus: CompiledCorpus,
    /// The emulation design point executed against (1,024-tile Clos,
    /// k = 255 — the corpus-benchmark point of §7.2).
    pub setup: EmulationSetup,
    /// The sequential baseline.
    pub seq: SequentialMachine,
    /// Instructions one full emulated-corpus pass executes.
    pub emulated_insts: u64,
    /// Instructions one full direct-corpus pass executes.
    pub direct_insts: u64,
}

/// Build the workload: compile + predecode the corpus, pick the design
/// point, and count the instructions a full pass executes (legacy and
/// decoded agree exactly, so one decoded pass suffices).
pub fn workload() -> Result<InterpWorkload> {
    let corpus = CompiledCorpus::compile()?;
    let setup = DesignPoint::clos(1024).mem_kb(128).k(255).build()?;
    let seq = SequentialMachine::paper_figures(false);
    let mut emulated_insts = 0u64;
    let mut direct_insts = 0u64;
    for p in &corpus.programs {
        let mut dmem = DirectMemory::new(seq, DIRECT_SPACE);
        let mut dm = FastMachine::new(&mut dmem, LOCAL_WORDS);
        direct_insts += dm.run(&p.direct)?.instructions;
        let mut emem = EmulatedChannelMemory::new(setup.clone());
        let mut em = FastMachine::new(&mut emem, LOCAL_WORDS);
        emulated_insts += em.run(&p.emulated)?.instructions;
    }
    Ok(InterpWorkload { corpus, setup, seq, emulated_insts, direct_insts })
}

/// Measure the four interpreter paths plus the decode-once cost;
/// honours `MEMCLOS_BENCH_QUICK` for the smoke mode.
pub fn measure(w: &InterpWorkload) -> Bench {
    let mut b = Bench::new("interp");

    b.iter_items("decoded-emulated", w.emulated_insts, || {
        let mut sum = 0u64;
        for p in &w.corpus.programs {
            let mut mem = EmulatedChannelMemory::new(w.setup.clone());
            let mut m = FastMachine::new(&mut mem, LOCAL_WORDS);
            sum += m.run(&p.emulated).expect("corpus runs").cycles;
        }
        black_box(sum)
    });
    b.iter_items("legacy-emulated", w.emulated_insts, || {
        let mut sum = 0u64;
        for p in &w.corpus.programs {
            let mut mem = EmulatedChannelMemory::new(w.setup.clone());
            let mut m = Machine::new(&mut mem, LOCAL_WORDS);
            sum += m.run(&p.emulated_code).expect("corpus runs").cycles;
        }
        black_box(sum)
    });
    b.iter_items("decoded-direct", w.direct_insts, || {
        let mut sum = 0u64;
        for p in &w.corpus.programs {
            let mut mem = DirectMemory::new(w.seq, DIRECT_SPACE);
            let mut m = FastMachine::new(&mut mem, LOCAL_WORDS);
            sum += m.run(&p.direct).expect("corpus runs").cycles;
        }
        black_box(sum)
    });
    b.iter_items("legacy-direct", w.direct_insts, || {
        let mut sum = 0u64;
        for p in &w.corpus.programs {
            let mut mem = DirectMemory::new(w.seq, DIRECT_SPACE);
            let mut m = Machine::new(&mut mem, LOCAL_WORDS);
            sum += m.run(&p.direct_code).expect("corpus runs").cycles;
        }
        black_box(sum)
    });
    b.iter("predecode-corpus", || {
        let mut ops = 0usize;
        for p in &w.corpus.programs {
            ops += predecode(&p.emulated_code).expect("corpus predecodes").len();
        }
        black_box(ops)
    });

    b
}

/// Speedup of the decoded loop over the legacy loop on the emulated
/// corpus (the acceptance metric).
pub fn speedup(b: &Bench) -> Result<f64> {
    let decoded = b.get("decoded-emulated").context("decoded-emulated not measured")?;
    let legacy = b.get("legacy-emulated").context("legacy-emulated not measured")?;
    Ok(legacy.median.as_secs_f64() / decoded.median.as_secs_f64())
}

/// Throughput assertions: the decoded interpreter must be >= 5x the
/// legacy enum-match loop on the emulated corpus, faster than legacy on
/// the direct corpus too, and every case measured with nonzero time.
pub fn assert_interp(b: &Bench) -> Result<()> {
    let x = speedup(b)?;
    anyhow::ensure!(
        x >= SPEEDUP_FLOOR,
        "decoded interpreter is only {x:.1}x the legacy enum-match loop \
         on the emulated corpus (need >= {SPEEDUP_FLOOR}x)"
    );
    let dd = b.get("decoded-direct").context("decoded-direct not measured")?;
    let ld = b.get("legacy-direct").context("legacy-direct not measured")?;
    anyhow::ensure!(
        dd.median < ld.median,
        "decoded direct path ({}) not faster than legacy ({})",
        fmt_duration(dd.median),
        fmt_duration(ld.median)
    );
    for case in
        ["decoded-emulated", "legacy-emulated", "decoded-direct", "legacy-direct", "predecode-corpus"]
    {
        let m = b.get(case).with_context(|| format!("{case} not measured"))?;
        anyhow::ensure!(!m.median.is_zero(), "{case} measured a zero median");
    }
    Ok(())
}

/// Human summary (one line per case + the speedup).
pub fn render(b: &Bench) -> String {
    let mut s = String::from("interpreter hot loop (cc corpus, 1,024-tile Clos k=255):\n");
    for m in b.results() {
        s.push_str(&format!("  {:<18} {:>12}/iter", m.name, fmt_duration(m.median)));
        if m.items > 0 {
            s.push_str(&format!("  {:>14.0} insts/s", m.throughput()));
        }
        s.push('\n');
    }
    if let Ok(x) = speedup(b) {
        s.push_str(&format!("  decoded vs legacy (emulated corpus): {x:.1}x\n"));
    }
    s
}

/// Measure the JIT tier against the legacy loop on the same corpus
/// and design point (the `jit` bench group). Native code is compiled
/// once, outside the timed closures — the compile-once cost gets its
/// own `jit-compile-corpus` case instead. Returns the typed
/// [`JitUnsupported`](crate::isa::JitUnsupported) error on hosts the
/// compiler does not target.
pub fn measure_jit(w: &InterpWorkload) -> Result<Bench> {
    if !jit::available() {
        return Err(crate::isa::JitUnsupported::host().into());
    }
    let jitted = JitCorpus::compile(&w.corpus)?;
    let mut b = Bench::new("jit");

    b.iter_items("jit-emulated", w.emulated_insts, || {
        let mut sum = 0u64;
        for p in &jitted.programs {
            let mut mem = EmulatedChannelMemory::new(w.setup.clone());
            let mut m = JitMachine::new(&mut mem, LOCAL_WORDS);
            sum += m.run(&p.emulated).expect("corpus runs").cycles;
        }
        black_box(sum)
    });
    b.iter_items("legacy-emulated", w.emulated_insts, || {
        let mut sum = 0u64;
        for p in &w.corpus.programs {
            let mut mem = EmulatedChannelMemory::new(w.setup.clone());
            let mut m = Machine::new(&mut mem, LOCAL_WORDS);
            sum += m.run(&p.emulated_code).expect("corpus runs").cycles;
        }
        black_box(sum)
    });
    b.iter_items("jit-direct", w.direct_insts, || {
        let mut sum = 0u64;
        for p in &jitted.programs {
            let mut mem = DirectMemory::new(w.seq, DIRECT_SPACE);
            let mut m = JitMachine::new(&mut mem, LOCAL_WORDS);
            sum += m.run(&p.direct).expect("corpus runs").cycles;
        }
        black_box(sum)
    });
    b.iter("jit-compile-corpus", || {
        let mut bytes = 0usize;
        for p in &w.corpus.programs {
            bytes += jit::compile(&p.emulated).expect("corpus compiles").code_len();
        }
        black_box(bytes)
    });

    Ok(b)
}

/// Speedup of the JIT tier over the legacy loop on the emulated
/// corpus (the third-tier acceptance metric).
pub fn jit_speedup(b: &Bench) -> Result<f64> {
    let native = b.get("jit-emulated").context("jit-emulated not measured")?;
    let legacy = b.get("legacy-emulated").context("legacy-emulated not measured")?;
    Ok(legacy.median.as_secs_f64() / native.median.as_secs_f64())
}

/// Throughput assertions for the third tier: the JIT must be >= 50x
/// the legacy enum-match loop on the emulated corpus, faster than
/// legacy on the direct corpus too, and every case measured with
/// nonzero time.
pub fn assert_jit(b: &Bench) -> Result<()> {
    let x = jit_speedup(b)?;
    anyhow::ensure!(
        x >= JIT_SPEEDUP_FLOOR,
        "baseline JIT is only {x:.1}x the legacy enum-match loop \
         on the emulated corpus (need >= {JIT_SPEEDUP_FLOOR}x)"
    );
    let jd = b.get("jit-direct").context("jit-direct not measured")?;
    for case in ["jit-emulated", "legacy-emulated", "jit-direct", "jit-compile-corpus"] {
        let m = b.get(case).with_context(|| format!("{case} not measured"))?;
        anyhow::ensure!(!m.median.is_zero(), "{case} measured a zero median");
    }
    anyhow::ensure!(
        jd.median < b.get("legacy-emulated").expect("checked above").median,
        "jit direct path ({}) not faster than the legacy emulated loop",
        fmt_duration(jd.median)
    );
    Ok(())
}

/// Human summary for the JIT group (one line per case + the speedup).
pub fn render_jit(b: &Bench) -> String {
    let mut s = String::from("baseline JIT tier (cc corpus, 1,024-tile Clos k=255):\n");
    for m in b.results() {
        s.push_str(&format!("  {:<18} {:>12}/iter", m.name, fmt_duration(m.median)));
        if m.items > 0 {
            s.push_str(&format!("  {:>14.0} insts/s", m.throughput()));
        }
        s.push('\n');
    }
    if let Ok(x) = jit_speedup(b) {
        s.push_str(&format!("  jit vs legacy (emulated corpus): {x:.1}x\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_measure_covers_jit_cases() {
        if !jit::available() {
            let err = measure_jit(&workload().unwrap()).unwrap_err();
            assert!(err.to_string().contains("JIT tier unsupported"), "{err}");
            return;
        }
        std::env::set_var("MEMCLOS_BENCH_QUICK", "1");
        let w = workload().unwrap();
        let b = measure_jit(&w).unwrap();
        for case in ["jit-emulated", "legacy-emulated", "jit-direct", "jit-compile-corpus"] {
            assert!(b.get(case).is_some(), "{case} missing");
        }
        assert!(jit_speedup(&b).unwrap() > 0.0);
        let json = b.to_json();
        assert!(json.contains("\"bench\": \"jit\""));
        let summary = render_jit(&b);
        assert!(summary.contains("jit vs legacy"));
    }

    #[test]
    fn quick_measure_covers_all_cases() {
        // Smoke: the cases and the JSON schema are present. (The 5x
        // floor is enforced by the bench binary / CLI, not here — unit
        // tests run unoptimised.)
        std::env::set_var("MEMCLOS_BENCH_QUICK", "1");
        let w = workload().unwrap();
        assert!(w.emulated_insts > w.direct_insts, "channel expansion adds instructions");
        let b = measure(&w);
        for case in [
            "decoded-emulated",
            "legacy-emulated",
            "decoded-direct",
            "legacy-direct",
            "predecode-corpus",
        ] {
            assert!(b.get(case).is_some(), "{case} missing");
        }
        assert!(speedup(&b).unwrap() > 0.0);
        let json = b.to_json();
        assert!(json.contains("\"bench\": \"interp\""));
        let summary = render(&b);
        assert!(summary.contains("decoded vs legacy"));
    }
}
