//! Generators for every table and figure in the paper's evaluation
//! (§5.1, §7). Each module produces the data rows (used by the benches
//! and tests), renders them as an ASCII table + plot matching the
//! paper's axes, and emits its full numeric output as a machine-
//! diffable [`Report`] — the documents the golden harness
//! (`tests/golden_figures.rs`) pins.
//!
//! | module | reproduces |
//! |--------|------------|
//! | [`tables`] | Tables 1–5 |
//! | [`fig5`] | Fig 5 — chip area vs tiles |
//! | [`fig6`] | Fig 6 — switch/wire/I-O area share |
//! | [`fig7`] | Fig 7 — interposer area |
//! | [`fig9`] | Fig 9 — absolute emulated-memory latency |
//! | [`fig10`] | Fig 10 — benchmark slowdown vs emulation size |
//! | [`fig11`] | Fig 11 — slowdown vs global-access fraction |
//! | [`binary_size`] | §7.3 — program binary growth |
//! | [`ablations`] | design-choice ablations (route-open, clock, switch degree, eDRAM) |
//! | [`contention`] | (extension) trace-driven contention lab — `c_cont` + tail latency vs clients × pattern |
//! | [`faults`] | (extension) fault injection — slowdown + p99 tail inflation vs fault fraction |
//! | [`scale`] | (extension) slowdown + `c_cont` from 1K to 1M tiles on computed routing |
//! | [`hotpath`] | (not in the paper) the repo's own access-hot-path perf trajectory |
//! | [`interp_bench`] | (not in the paper) decoded-vs-legacy interpreter perf trajectory |
//!
//! Every evaluating figure runs on the [`ParallelSweep`] engine. A
//! figure invoked standalone builds a fresh engine from its [`FigOpts`];
//! `memclos figures --all` (and the golden harness) build ONE engine and
//! pass it to every `generate_with`, so the memoizing result cache pays
//! off across figures — figs 9/10/11 share their latency sweep points,
//! figs 5/6 share their single-chip floorplans.

pub mod ablations;
pub mod binary_size;
pub mod contention;
pub mod faults;
pub mod fig10;
pub mod fig11;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig9;
pub mod hotpath;
pub mod interp_bench;
pub mod scale;
pub mod tables;

use anyhow::Result;

use crate::api::{Mode, Report, Tech};
use crate::config::Doc;
use crate::coordinator::{default_jobs, ParallelSweep};
use crate::emulation::TopologyKind;

/// Shared options for figure generation: backend selection, sweep
/// parallelism and the technology bundle every design point is built
/// from (so `--set`/`--config` overrides reach the figures).
#[derive(Clone, Debug)]
pub struct FigOpts {
    /// Evaluation mode for latency points.
    pub mode: Mode,
    /// Worker threads for sweeps (1 forces the sequential oracle).
    pub jobs: usize,
    /// Base seed.
    pub seed: u64,
    /// Technology/model parameters (Tables 1, 2 and 5).
    pub tech: Tech,
}

impl Default for FigOpts {
    fn default() -> Self {
        Self { mode: Mode::Exact, jobs: default_jobs(), seed: 0xC105, tech: Tech::default() }
    }
}

impl FigOpts {
    /// Production defaults: XLA hot path when artifacts exist, native
    /// Monte-Carlo otherwise.
    pub fn auto() -> Self {
        Self { mode: Mode::Auto { samples: 65_536, batch: 16_384 }, ..Self::default() }
    }

    /// Exact mode with the technology overrides of a config doc.
    pub fn from_doc(doc: &Doc) -> Self {
        Self { tech: Tech::from_doc(doc), ..Self::default() }
    }

    /// The sweep engine these options describe. Build it once and share
    /// it across figures to share the result caches.
    pub fn engine(&self) -> ParallelSweep {
        ParallelSweep::new(self.mode, &self.tech, self.jobs, self.seed)
    }
}

/// Topology label used across the figure datasets.
pub fn topo_str(kind: TopologyKind) -> &'static str {
    match kind {
        TopologyKind::Clos => "clos",
        TopologyKind::Mesh => "mesh",
    }
}

/// Every figure's and table's full numeric output as machine-diffable
/// [`Report`]s, generated through ONE shared engine — exactly the
/// documents the golden harness pins and `memclos figures --all --json`
/// emits. (The perf-trajectory extras `hotpath`/`interp_bench` are
/// wall-clock measurements and deliberately not part of this set.)
pub fn all_reports(engine: &ParallelSweep) -> Result<Vec<Report>> {
    let mut out = tables::reports(engine.tech());
    out.push(fig5::report(&fig5::generate_with(engine)?));
    out.push(fig6::report(&fig6::generate_with(engine)?));
    out.push(fig7::report(&fig7::generate_with(engine)?));
    out.push(fig9::report(&fig9::generate_with(engine)?));
    out.push(fig10::report(&fig10::generate_with(engine)?));
    out.push(fig11::report(&fig11::generate_with(engine)?));
    out.push(binary_size::report(&binary_size::generate()?));
    out.push(ablations::report(&ablations::generate_with(engine)?));
    out.push(contention::report(&contention::generate_with(engine)?));
    out.push(faults::report(&faults::generate_with(engine)?));
    out.push(scale::report(&scale::generate_with(engine)?));
    Ok(out)
}
