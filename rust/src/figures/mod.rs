//! Generators for every table and figure in the paper's evaluation
//! (§5.1, §7). Each module produces the data rows (used by the benches
//! and tests) and renders them as an ASCII table + plot matching the
//! paper's axes.
//!
//! | module | reproduces |
//! |--------|------------|
//! | [`tables`] | Tables 1–5 |
//! | [`fig5`] | Fig 5 — chip area vs tiles |
//! | [`fig6`] | Fig 6 — switch/wire/I-O area share |
//! | [`fig7`] | Fig 7 — interposer area |
//! | [`fig9`] | Fig 9 — absolute emulated-memory latency |
//! | [`fig10`] | Fig 10 — benchmark slowdown vs emulation size |
//! | [`fig11`] | Fig 11 — slowdown vs global-access fraction |
//! | [`binary_size`] | §7.3 — program binary growth |
//! | [`ablations`] | design-choice ablations (route-open, clock, switch degree, eDRAM) |
//! | [`hotpath`] | (not in the paper) the repo's own access-hot-path perf trajectory |
//! | [`interp_bench`] | (not in the paper) decoded-vs-legacy interpreter perf trajectory |

pub mod ablations;
pub mod binary_size;
pub mod fig10;
pub mod fig11;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig9;
pub mod hotpath;
pub mod interp_bench;
pub mod tables;

use crate::api::{Mode, Tech};
use crate::config::Doc;

/// Shared options for figure generation: backend selection, sweep
/// parallelism and the technology bundle every design point is built
/// from (so `--set`/`--config` overrides reach the figures).
#[derive(Clone, Debug)]
pub struct FigOpts {
    /// Evaluation mode for latency points.
    pub mode: Mode,
    /// Worker threads for sweeps.
    pub workers: usize,
    /// Base seed.
    pub seed: u64,
    /// Technology/model parameters (Tables 1, 2 and 5).
    pub tech: Tech,
}

impl Default for FigOpts {
    fn default() -> Self {
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self { mode: Mode::Exact, workers, seed: 0xC105, tech: Tech::default() }
    }
}

impl FigOpts {
    /// Production defaults: XLA hot path when artifacts exist, native
    /// Monte-Carlo otherwise.
    pub fn auto() -> Self {
        Self { mode: Mode::Auto { samples: 65_536, batch: 16_384 }, ..Self::default() }
    }

    /// Exact mode with the technology overrides of a config doc.
    pub fn from_doc(doc: &Doc) -> Self {
        Self { tech: Tech::from_doc(doc), ..Self::default() }
    }
}
