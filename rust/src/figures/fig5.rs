//! Fig 5: total chip area vs number of tiles, folded Clos and 2D mesh,
//! for 64–512 KB tile memories, against the 80–140 mm^2 economical
//! band.

use anyhow::Result;

use super::topo_str;
use crate::api::{Mode, Report, Tech};
use crate::coordinator::{ParallelSweep, PlanPoint};
use crate::emulation::TopologyKind;
use crate::tech::ChipTech;
use crate::util::plot::Plot;
use crate::util::table::{f, Table};

/// One data point.
#[derive(Clone, Copy, Debug)]
pub struct Row {
    /// "clos" or "mesh".
    pub topo: &'static str,
    /// Tiles on the (single) chip.
    pub tiles: usize,
    /// Tile memory, KB.
    pub mem_kb: u32,
    /// Total chip area, mm^2.
    pub area_mm2: f64,
    /// Falls in the economical band.
    pub economical: bool,
}

/// Tile counts plotted (square grids so the mesh is constructible).
pub const TILE_POINTS: &[usize] = &[16, 64, 256, 1024];

/// Memory capacities plotted.
pub const MEM_POINTS: &[u32] = &[64, 128, 256, 512];

/// The figure's plan grid, in render order. Single-chip layouts: the
/// figure studies how much fits on one die (the engine's plan evaluator
/// uses the integer-validated grid — the seed's
/// `((tiles/16) as f64).sqrt() as usize` silently truncated at
/// non-power-of-4 tile counts).
pub fn plan_points() -> Vec<PlanPoint> {
    let mut pts = Vec::new();
    for &mem in MEM_POINTS {
        for &tiles in TILE_POINTS {
            pts.push(PlanPoint { kind: TopologyKind::Clos, tiles, mem_kb: mem });
            pts.push(PlanPoint { kind: TopologyKind::Mesh, tiles, mem_kb: mem });
        }
    }
    pts
}

/// Generate the Fig 5 dataset on a shared sweep engine (figs 5 and 6
/// share the single-chip floorplan cache).
pub fn generate_with(engine: &ParallelSweep) -> Result<Vec<Row>> {
    let plans = engine.eval_plans(&plan_points())?;
    Ok(plans
        .iter()
        .map(|p| Row {
            topo: topo_str(p.point.kind),
            tiles: p.point.tiles,
            mem_kb: p.point.mem_kb,
            area_mm2: p.area_mm2,
            economical: p.economical,
        })
        .collect())
}

/// Generate the Fig 5 dataset (standalone: a fresh engine).
pub fn generate(tech: &ChipTech) -> Result<Vec<Row>> {
    let tech = Tech { chip: tech.clone(), ..Tech::default() };
    generate_with(&ParallelSweep::with_defaults(Mode::Exact, &tech))
}

/// Full numeric output for the golden harness.
pub fn report(rows: &[Row]) -> Report {
    let mut rep = Report::new("fig5");
    for r in rows {
        rep.push(
            crate::api::Row::new(&format!("{}-{}t-{}KB", r.topo, r.tiles, r.mem_kb))
                .int("tiles", r.tiles as u64)
                .int("mem_kb", r.mem_kb as u64)
                .num("area_mm2", r.area_mm2)
                .int("economical", r.economical as u64),
        );
    }
    rep
}

/// Render the dataset as a table + the paper's log-linear plot.
pub fn render(rows: &[Row], tech: &ChipTech) -> String {
    let mut t = Table::new(&["topo", "tiles", "mem KB", "area mm^2", "economical"])
        .with_title("Fig 5: total chip area vs tiles");
    for r in rows {
        t.row(&[
            r.topo.to_string(),
            r.tiles.to_string(),
            r.mem_kb.to_string(),
            f(r.area_mm2, 1),
            if r.economical { "yes".into() } else { "".into() },
        ]);
    }
    let mut plot = Plot::new("Fig 5: chip area (mm^2) vs tiles (log2)", "tiles", "mm^2");
    for &mem in MEM_POINTS {
        for topo in ["clos", "mesh"] {
            let pts: Vec<(f64, f64)> = rows
                .iter()
                .filter(|r| r.topo == topo && r.mem_kb == mem)
                .map(|r| (r.tiles as f64, r.area_mm2))
                .collect();
            plot.series(&format!("{topo}-{mem}KB"), &pts);
        }
    }
    plot.hline(tech.econ_min_mm2, "Min economical");
    plot.hline(tech.econ_max_mm2, "Max economical");
    format!("{}\n{}", t.render(), plot.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_paper() {
        let tech = ChipTech::default();
        let rows = generate(&tech).unwrap();
        assert_eq!(rows.len(), TILE_POINTS.len() * MEM_POINTS.len() * 2);
        // Clos >= mesh at every shared point; monotone in tiles & mem.
        for &mem in MEM_POINTS {
            for &tiles in TILE_POINTS {
                let clos = rows
                    .iter()
                    .find(|r| r.topo == "clos" && r.tiles == tiles && r.mem_kb == mem)
                    .unwrap();
                let mesh = rows
                    .iter()
                    .find(|r| r.topo == "mesh" && r.tiles == tiles && r.mem_kb == mem)
                    .unwrap();
                assert!(
                    clos.area_mm2 >= mesh.area_mm2 * 0.95,
                    "clos {} < mesh {} at tiles={tiles} mem={mem}",
                    clos.area_mm2,
                    mesh.area_mm2
                );
            }
        }
        // Some configurations land in the economical band (the paper's
        // candidate designs) and some exceed it.
        assert!(rows.iter().any(|r| r.economical));
        assert!(rows.iter().any(|r| r.area_mm2 > tech.econ_max_mm2));
    }

    #[test]
    fn renders() {
        let tech = ChipTech::default();
        let rows = generate(&tech).unwrap();
        let s = render(&rows, &tech);
        assert!(s.contains("Fig 5"));
        assert!(s.contains("Min economical"));
    }
}
