//! Fig 5: total chip area vs number of tiles, folded Clos and 2D mesh,
//! for 64–512 KB tile memories, against the 80–140 mm^2 economical
//! band.

use anyhow::Result;

use crate::tech::ChipTech;
use crate::topology::{ClosSpec, MeshSpec};
use crate::util::plot::Plot;
use crate::util::table::{f, Table};
use crate::vlsi::{ClosFloorplan, MeshFloorplan};

/// One data point.
#[derive(Clone, Copy, Debug)]
pub struct Row {
    /// "clos" or "mesh".
    pub topo: &'static str,
    /// Tiles on the (single) chip.
    pub tiles: usize,
    /// Tile memory, KB.
    pub mem_kb: u32,
    /// Total chip area, mm^2.
    pub area_mm2: f64,
    /// Falls in the economical band.
    pub economical: bool,
}

/// Tile counts plotted (square grids so the mesh is constructible).
pub const TILE_POINTS: &[usize] = &[16, 64, 256, 1024];

/// Memory capacities plotted.
pub const MEM_POINTS: &[u32] = &[64, 128, 256, 512];

/// Generate the Fig 5 dataset.
pub fn generate(tech: &ChipTech) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for &mem in MEM_POINTS {
        for &tiles in TILE_POINTS {
            // Single-chip layouts: the figure studies how much fits on
            // one die.
            let clos_spec =
                ClosSpec { tiles, tiles_per_chip: tiles.max(256), ..ClosSpec::default() };
            let clos = ClosFloorplan::plan(&clos_spec, mem, tech)?;
            rows.push(Row {
                topo: "clos",
                tiles,
                mem_kb: mem,
                area_mm2: clos.area_mm2,
                economical: clos.is_economical(tech),
            });
            // Integer-validated single-chip grid: the seed's
            // `(tiles/16) as f64).sqrt() as usize` silently truncated
            // at non-power-of-4 tile counts.
            let mesh_spec = MeshSpec::single_chip(tiles)?;
            let mesh = MeshFloorplan::plan(&mesh_spec, mem, tech)?;
            rows.push(Row {
                topo: "mesh",
                tiles,
                mem_kb: mem,
                area_mm2: mesh.area_mm2,
                economical: mesh.is_economical(tech),
            });
        }
    }
    Ok(rows)
}

/// Render the dataset as a table + the paper's log-linear plot.
pub fn render(rows: &[Row], tech: &ChipTech) -> String {
    let mut t = Table::new(&["topo", "tiles", "mem KB", "area mm^2", "economical"])
        .with_title("Fig 5: total chip area vs tiles");
    for r in rows {
        t.row(&[
            r.topo.to_string(),
            r.tiles.to_string(),
            r.mem_kb.to_string(),
            f(r.area_mm2, 1),
            if r.economical { "yes".into() } else { "".into() },
        ]);
    }
    let mut plot = Plot::new("Fig 5: chip area (mm^2) vs tiles (log2)", "tiles", "mm^2");
    for &mem in MEM_POINTS {
        for topo in ["clos", "mesh"] {
            let pts: Vec<(f64, f64)> = rows
                .iter()
                .filter(|r| r.topo == topo && r.mem_kb == mem)
                .map(|r| (r.tiles as f64, r.area_mm2))
                .collect();
            plot.series(&format!("{topo}-{mem}KB"), &pts);
        }
    }
    plot.hline(tech.econ_min_mm2, "Min economical");
    plot.hline(tech.econ_max_mm2, "Max economical");
    format!("{}\n{}", t.render(), plot.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_paper() {
        let tech = ChipTech::default();
        let rows = generate(&tech).unwrap();
        assert_eq!(rows.len(), TILE_POINTS.len() * MEM_POINTS.len() * 2);
        // Clos >= mesh at every shared point; monotone in tiles & mem.
        for &mem in MEM_POINTS {
            for &tiles in TILE_POINTS {
                let clos = rows
                    .iter()
                    .find(|r| r.topo == "clos" && r.tiles == tiles && r.mem_kb == mem)
                    .unwrap();
                let mesh = rows
                    .iter()
                    .find(|r| r.topo == "mesh" && r.tiles == tiles && r.mem_kb == mem)
                    .unwrap();
                assert!(
                    clos.area_mm2 >= mesh.area_mm2 * 0.95,
                    "clos {} < mesh {} at tiles={tiles} mem={mem}",
                    clos.area_mm2,
                    mesh.area_mm2
                );
            }
        }
        // Some configurations land in the economical band (the paper's
        // candidate designs) and some exceed it.
        assert!(rows.iter().any(|r| r.economical));
        assert!(rows.iter().any(|r| r.area_mm2 > tech.econ_max_mm2));
    }

    #[test]
    fn renders() {
        let tech = ChipTech::default();
        let rows = generate(&tech).unwrap();
        let s = render(&rows, &tech);
        assert!(s.contains("Fig 5"));
        assert!(s.contains("Min economical"));
    }
}
