//! Fig 9: absolute emulated-memory random-access latency vs emulation
//! size, for 1,024- and 4,096-tile systems, against the DDR3 baseline.

use anyhow::Result;

use super::{topo_str, FigOpts};
use crate::api::Report;
use crate::coordinator::{ParallelSweep, SweepPoint};
use crate::emulation::{SequentialMachine, TopologyKind};
use crate::util::plot::Plot;
use crate::util::table::{f, Table};

/// One data point.
#[derive(Clone, Copy, Debug)]
pub struct Row {
    /// System tiles.
    pub system: usize,
    /// "clos" or "mesh".
    pub topo: &'static str,
    /// Emulation size (memory tiles).
    pub k: usize,
    /// Mean random-access latency, ns (cycles at 1 GHz).
    pub latency_ns: f64,
}

/// Fig 9 dataset plus the measured DDR3 baseline.
#[derive(Clone, Debug)]
pub struct Fig9 {
    /// Data rows.
    pub rows: Vec<Row>,
    /// Measured DDR3 random-access latency, ns.
    pub ddr3_ns: f64,
}

/// Systems plotted.
pub const SYSTEMS: &[usize] = &[1024, 4096];

/// Tile memory used.
pub const MEM_KB: u32 = 128;

/// Emulation sizes: powers of two up to the system size.
pub fn k_points(system: usize) -> Vec<usize> {
    let mut ks: Vec<usize> = (4..)
        .map(|i| 1usize << i)
        .take_while(|&k| k < system)
        .collect();
    ks.push(system - 1); // full emulation
    ks
}

/// The figure's latency sweep, in generation order. Fig 10 sweeps the
/// same points, so on a shared engine its analytic rows are served
/// entirely from the result cache.
pub fn sweep_points() -> Vec<SweepPoint> {
    let mut points = Vec::new();
    for &system in SYSTEMS {
        for kind in [TopologyKind::Clos, TopologyKind::Mesh] {
            for k in k_points(system) {
                points.push(SweepPoint { kind, tiles: system, mem_kb: MEM_KB, k });
            }
        }
    }
    points
}

/// Generate the Fig 9 dataset on a shared sweep engine.
pub fn generate_with(engine: &ParallelSweep) -> Result<Fig9> {
    let results = engine.eval_points(&sweep_points())?;
    let mut rows: Vec<Row> = results
        .iter()
        .map(|r| Row {
            system: r.point.tiles,
            topo: topo_str(r.point.kind),
            k: r.point.k,
            latency_ns: r.mean_cycles,
        })
        .collect();
    rows.sort_by_key(|r| (r.system, r.topo, r.k));
    let ddr3_ns = SequentialMachine::with_measured_dram(1).dram_ns;
    Ok(Fig9 { rows, ddr3_ns })
}

/// Generate the Fig 9 dataset (standalone: a fresh engine).
pub fn generate(opts: &FigOpts) -> Result<Fig9> {
    generate_with(&opts.engine())
}

/// Full numeric output for the golden harness.
pub fn report(fig: &Fig9) -> Report {
    let mut rep = Report::new("fig9");
    rep.push(crate::api::Row::new("ddr3-baseline").num("latency_ns", fig.ddr3_ns));
    for r in &fig.rows {
        rep.push(
            crate::api::Row::new(&format!("{}-{}t-k{}", r.topo, r.system, r.k))
                .int("system", r.system as u64)
                .int("k", r.k as u64)
                .num("latency_ns", r.latency_ns)
                .num("vs_ddr3", r.latency_ns / fig.ddr3_ns),
        );
    }
    rep
}

/// Render the dataset.
pub fn render(fig: &Fig9) -> String {
    let mut out = String::new();
    let mut t = Table::new(&["system", "topo", "k tiles", "latency ns", "vs DDR3"])
        .with_title("Fig 9: absolute memory latency");
    for r in &fig.rows {
        t.row(&[
            r.system.to_string(),
            r.topo.to_string(),
            r.k.to_string(),
            f(r.latency_ns, 1),
            format!("{}x", f(r.latency_ns / fig.ddr3_ns, 2)),
        ]);
    }
    out.push_str(&t.render());
    for &system in SYSTEMS {
        let mut plot = Plot::new(
            &format!("Fig 9 ({system}-tile system): latency (ns) vs emulation tiles (log2)"),
            "emulation tiles",
            "ns",
        );
        for topo in ["clos", "mesh"] {
            let pts: Vec<(f64, f64)> = fig
                .rows
                .iter()
                .filter(|r| r.system == system && r.topo == topo)
                .map(|r| (r.k as f64, r.latency_ns))
                .collect();
            plot.series(topo, &pts);
        }
        plot.hline(fig.ddr3_ns, "DDR3 baseline");
        out.push('\n');
        out.push_str(&plot.render());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_shape_holds() {
        let fig = generate(&FigOpts::default()).unwrap();
        // DDR3 baseline ~35 ns.
        assert!((fig.ddr3_ns - 35.0).abs() < 2.0);

        for &system in SYSTEMS {
            let clos: Vec<&Row> =
                fig.rows.iter().filter(|r| r.system == system && r.topo == "clos").collect();
            // monotone nondecreasing in k
            for w in clos.windows(2) {
                assert!(w[1].latency_ns >= w[0].latency_ns - 1e-9);
            }
            // small emulations beat DDR3 (§7.2: speedup up to 16 tiles)
            assert!(clos[0].latency_ns < fig.ddr3_ns, "{}", clos[0].latency_ns);
            // full emulation within factor 2-5 of DDR3 (§7.1)
            let full = clos.last().unwrap();
            let ratio = full.latency_ns / fig.ddr3_ns;
            assert!((2.0..5.0).contains(&ratio), "system={system}: ratio {ratio}");
        }

        // mesh deteriorates relative to clos at the large multi-chip
        // system (§7.1: 30-40% overhead; we accept >10%).
        let clos4k = fig
            .rows
            .iter()
            .find(|r| r.system == 4096 && r.topo == "clos" && r.k == 4095)
            .unwrap();
        let mesh4k = fig
            .rows
            .iter()
            .find(|r| r.system == 4096 && r.topo == "mesh" && r.k == 4095)
            .unwrap();
        let overhead = mesh4k.latency_ns / clos4k.latency_ns;
        assert!(overhead > 1.1, "mesh/clos = {overhead}");
    }

    #[test]
    fn config_overrides_reach_the_figure() {
        // Regression: `figure 9 --set net.t_mem=...` used to be
        // silently dropped — figures hard-coded default tech. A t_mem
        // override must now shift every latency row by the same amount.
        let base = generate(&FigOpts::default()).unwrap();
        let doc = crate::config::Doc::parse("[net]\nt_mem = 21.0").unwrap();
        let tweaked = generate(&FigOpts::from_doc(&doc)).unwrap();
        assert_eq!(base.rows.len(), tweaked.rows.len());
        for (b, t) in base.rows.iter().zip(&tweaked.rows) {
            assert_eq!((b.system, b.topo, b.k), (t.system, t.topo, t.k));
            assert!(
                (t.latency_ns - (b.latency_ns + 20.0)).abs() < 1e-9,
                "k={} {}: {} vs {} + 20",
                b.k,
                b.topo,
                t.latency_ns,
                b.latency_ns
            );
        }
    }
}
