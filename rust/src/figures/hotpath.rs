//! Hot-path throughput measurement — the repo's own perf trajectory,
//! not a paper figure.
//!
//! Measures the per-access cost of the emulated memory across the four
//! layers that serve it, on the paper's largest design point (4,096-tile
//! folded Clos, k = 4,095):
//!
//! | case | path |
//! |------|------|
//! | `native-65536` | rank-LUT batch ([`EmulationSetup::native_batch`]) |
//! | `routed-65536` | seed route-per-access reference ([`EmulationSetup::native_batch_routed`]) |
//! | `exact-closed-form` | stored-mean expectation |
//! | `des-access` | DES round trips over the next-hop/port-arena sim |
//! | `interp-load` | interpreter channel-protocol loads (paged store + LUT) |
//!
//! [`assert_hotpath`] encodes the acceptance floor (LUT >= 10x the
//! routed reference on the batch path); [`Bench::write_json`] emits the
//! `BENCH_hotpath.json` schema consumed by
//! `rust/scripts/bench_hotpath.sh` so successive PRs can diff perf.

use anyhow::{Context, Result};

use crate::api::DesignPoint;
use crate::emulation::controller::expand_load;
use crate::emulation::EmulationSetup;
use crate::isa::inst::Inst;
use crate::isa::interp::{EmulatedChannelMemory, Machine};
use crate::sim::NetworkSim;
use crate::util::bench::{black_box, fmt_duration, Bench};
use crate::util::rng::Rng;

/// Addresses per batch-path iteration (the acceptance criterion's
/// batch size).
pub const BATCH: usize = 65_536;

/// DES round trips per `des-access` iteration.
const DES_ACCESSES: usize = 1024;

/// Channel-protocol loads per `interp-load` iteration.
const INTERP_LOADS: usize = 1024;

/// The design point the hot path is measured on (4,096-tile Clos
/// emulating over k = 4,095 tiles, 128 KB each).
pub fn design_point() -> Result<EmulationSetup> {
    DesignPoint::clos(4096).mem_kb(128).k(4095).build()
}

/// Measure the native, DES and interpreter hot paths; honours
/// `MEMCLOS_BENCH_QUICK` for the smoke mode.
pub fn measure(setup: &EmulationSetup) -> Bench {
    let space = setup.map.space_words();
    let mut rng = Rng::new(42);
    let mut b = Bench::new("hotpath");

    // Native batch: LUT path vs the seed's route-per-access reference.
    let mut addrs = vec![0i32; BATCH];
    rng.fill_addresses(space, &mut addrs);
    let mut out = Vec::new();
    b.iter_items("native-65536", BATCH as u64, || {
        setup.native_batch(&addrs, &mut out);
        black_box(out.len())
    });
    b.iter_items("routed-65536", BATCH as u64, || {
        setup.native_batch_routed(&addrs, &mut out);
        black_box(out.len())
    });
    b.iter("exact-closed-form", || black_box(setup.expected_latency()));

    // DES: dependent round trips through the next-hop/port-arena sim.
    let mut sim = NetworkSim::new(&setup.topo, &setup.model);
    let client = setup.map.client;
    let tiles = setup.map.tiles;
    let mut now = 0u64;
    let mut tile = client;
    b.iter_items("des-access", DES_ACCESSES as u64, || {
        for _ in 0..DES_ACCESSES {
            tile = (tile + 1) % tiles;
            if tile == client {
                tile = (tile + 1) % tiles;
            }
            now = sim.access(client, tile, now);
        }
        black_box(now)
    });

    // Interpreter: channel-protocol loads through the paged store + LUT.
    let mut prog: Vec<Inst> = vec![Inst::LoadImm { d: 1, imm: 1000 }];
    for _ in 0..INTERP_LOADS {
        prog.extend(expand_load(2, 1));
    }
    prog.push(Inst::Halt);
    let mut mem = EmulatedChannelMemory::new(setup.clone());
    b.iter_items("interp-load", INTERP_LOADS as u64, || {
        let mut m = Machine::new(&mut mem, 64);
        black_box(m.run(&prog).expect("interp bench program runs").cycles)
    });

    b
}

/// Speedup of the LUT batch path over the routed reference.
pub fn lut_speedup(b: &Bench) -> Result<f64> {
    let native = b.get("native-65536").context("native-65536 not measured")?;
    let routed = b.get("routed-65536").context("routed-65536 not measured")?;
    Ok(routed.median.as_secs_f64() / native.median.as_secs_f64())
}

/// Throughput assertions: the LUT path must be >= 10x the seed's
/// route-per-access path at the 65,536-address batch, sustain at least
/// 10 M addresses/s, and the DES + interpreter paths must have been
/// measured with nonzero throughput.
pub fn assert_hotpath(b: &Bench) -> Result<()> {
    let speedup = lut_speedup(b)?;
    anyhow::ensure!(
        speedup >= 10.0,
        "LUT batch path is only {speedup:.1}x the route-per-access reference (need >= 10x)"
    );
    let native = b.get("native-65536").context("native-65536 not measured")?;
    anyhow::ensure!(
        native.throughput() >= 1e7,
        "native batch throughput {:.0} addrs/s below the 10 M floor",
        native.throughput()
    );
    for case in ["des-access", "interp-load"] {
        let m = b.get(case).with_context(|| format!("{case} not measured"))?;
        anyhow::ensure!(m.throughput() > 0.0, "{case} throughput is zero");
    }
    Ok(())
}

/// Human summary of the measurements (one line per case + speedup).
pub fn render(setup: &EmulationSetup, b: &Bench) -> String {
    let mut s = format!(
        "hot path ({} {}-tile system, k={}):\n",
        setup.topo.name(),
        setup.map.tiles,
        setup.map.k
    );
    for m in b.results() {
        s.push_str(&format!("  {:<18} {:>12}/iter", m.name, fmt_duration(m.median)));
        if m.items > 0 {
            s.push_str(&format!("  {:>14.0} addrs/s", m.throughput()));
        }
        s.push('\n');
    }
    if let Ok(x) = lut_speedup(b) {
        s.push_str(&format!("  LUT vs route-per-access: {x:.1}x\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_measure_covers_all_paths() {
        // Smoke: run the measurement in quick mode and check the cases
        // and the JSON schema are all present. (The 10x assertion is
        // exercised by the bench binary, not here — unit tests run
        // unoptimised.)
        std::env::set_var("MEMCLOS_BENCH_QUICK", "1");
        let setup = DesignPoint::clos(256).mem_kb(64).k(255).build().unwrap();
        let b = measure(&setup);
        for case in
            ["native-65536", "routed-65536", "exact-closed-form", "des-access", "interp-load"]
        {
            assert!(b.get(case).is_some(), "{case} missing");
        }
        assert!(lut_speedup(&b).unwrap() > 0.0);
        let json = b.to_json();
        assert!(json.contains("\"bench\": \"hotpath\""));
        let summary = render(&setup, &b);
        assert!(summary.contains("clos 256-tile system, k=255"));
        assert!(summary.contains("LUT vs route-per-access"));
    }
}
