//! Ablations of the paper's design choices — experiments the paper
//! discusses qualitatively but does not plot:
//!
//! * **Held-open routes** (§6.3): how much of the latency is the
//!   `t_open` route-setup cost?
//! * **Clock scaling** (§7.1): "an increase in clock speed for the
//!   parallel system would improve latency because the network would
//!   operate faster" — while the DRAM's intrinsic latency is fixed.
//! * **Switch degree** (§2): degree-64 switches halve the stage count
//!   sooner but quadruple the crossbar area.
//! * **eDRAM tiles** (§3.2/§5.0.3): the memory technology the paper
//!   rejected on manufacturing-cost grounds — denser tiles, slower
//!   access.

use anyhow::Result;

use crate::emulation::{EmulationSetup, SequentialMachine, TopologyKind};
use crate::netmodel::{LatencyModel, NetParams};
use crate::tech::{ChipTech, InterposerTech, MemTech};
use crate::topology::{ClosSpec, FoldedClos, Topology};
use crate::util::table::{f, Table};

/// One ablation data point.
#[derive(Clone, Debug)]
pub struct Row {
    /// Experiment name.
    pub experiment: &'static str,
    /// Variant label.
    pub variant: String,
    /// Full-emulation mean access latency, ns.
    pub latency_ns: f64,
    /// Dhrystone-mix slowdown vs the DDR3 sequential machine.
    pub slowdown: f64,
    /// Note (area cost etc.).
    pub note: String,
}

fn slowdown(latency: f64, dram_ns: f64) -> f64 {
    crate::workload::predict_slowdown(&crate::workload::DHRYSTONE_MIX, latency, dram_ns)
}

/// Ablation 1: pay `t_open` per access vs hold routes open.
pub fn route_open(dram_ns: f64) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for (label, open) in [("closed routes (paper)", false), ("routes held open", true)] {
        let net = NetParams { route_open: open, ..NetParams::default() };
        let setup = EmulationSetup::build(
            TopologyKind::Clos,
            4096,
            128,
            4095,
            net,
            &ChipTech::default(),
            &InterposerTech::default(),
        )?;
        let lat = setup.expected_latency();
        rows.push(Row {
            experiment: "route_open",
            variant: label.to_string(),
            latency_ns: lat,
            slowdown: slowdown(lat, dram_ns),
            note: if open { "requires per-client circuit reservation".into() } else { String::new() },
        });
    }
    Ok(rows)
}

/// Ablation 2: clock the parallel machine at 1/2/4 GHz while the DRAM
/// baseline keeps its intrinsic latency.
pub fn clock_scaling(dram_ns: f64) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for ghz in [1.0f64, 2.0, 4.0] {
        let chip = ChipTech { clock_ghz: ghz, ..ChipTech::default() };
        let setup = EmulationSetup::build(
            TopologyKind::Clos,
            4096,
            128,
            4095,
            NetParams::default(),
            &chip,
            &InterposerTech::default(),
        )?;
        // Cycles shrink in wall-clock as the clock rises; wire spans
        // re-pipeline to more cycles automatically via the floorplan.
        let lat_ns = setup.expected_latency() / ghz;
        rows.push(Row {
            experiment: "clock_scaling",
            variant: format!("{ghz} GHz network"),
            latency_ns: lat_ns,
            slowdown: slowdown(lat_ns, dram_ns),
            note: "DRAM latency is intrinsic (unchanged)".into(),
        });
    }
    Ok(rows)
}

/// Ablation 3: degree-64 switches (32 tiles/edge switch, 1,024
/// tiles/chip — exceeds the economical die, as §2 notes).
pub fn switch_degree(dram_ns: f64) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    // Baseline: degree-32 (the paper's design).
    let base = EmulationSetup::default_tech(TopologyKind::Clos, 4096, 128, 4095)?;
    let lat32 = base.expected_latency();
    rows.push(Row {
        experiment: "switch_degree",
        variant: "degree-32 (paper)".into(),
        latency_ns: lat32,
        slowdown: slowdown(lat32, dram_ns),
        note: "256-tile chips fit the economical band".into(),
    });

    // Degree-64: a crossbar is ~O(degree^2) area.
    let spec = ClosSpec { tiles: 4096, tiles_per_edge: 32, tiles_per_chip: 1024, degree: 64 };
    let chip64 = ChipTech { switch_area_mm2: 0.20, ..ChipTech::default() };
    let fp = crate::vlsi::ClosFloorplan::plan(&spec, 128, &chip64)?;
    let pkg = crate::vlsi::PackagedSystem::clos(spec.chips(), &fp, &chip64, &InterposerTech::default())?;
    let links = crate::netmodel::LinkLatencies {
        tile: fp.cycles.tile as f64,
        edge_core: fp.cycles.edge_core as f64,
        core_sys: (2 * fp.cycles.core_pad + pkg.interposer_cycles) as f64,
        mesh_hop: 0.0,
        mesh_cross_extra: 0.0,
    };
    let topo = Topology::Clos(FoldedClos::build(spec)?);
    let model = LatencyModel::new(NetParams::default(), links);
    let map = crate::emulation::AddressMap::new(15, 4095, 0, 4096);
    let mut sum = 0.0;
    for r in 0..map.k {
        sum += model.access(&topo, map.client, map.tile_of_rank(r));
    }
    let lat64 = sum / map.k as f64;
    rows.push(Row {
        experiment: "switch_degree",
        variant: "degree-64".into(),
        latency_ns: lat64,
        slowdown: slowdown(lat64, dram_ns),
        note: format!("chip {} mm^2 — far beyond the economical band", f(fp.area_mm2, 0)),
    });
    Ok(rows)
}

/// Ablation 4: eDRAM tile memories — ~2.4x denser (smaller chips,
/// shorter wires) but 1.3 ns access (2 cycles) and costlier process.
pub fn edram_tiles(dram_ns: f64) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    let base = EmulationSetup::default_tech(TopologyKind::Clos, 4096, 128, 4095)?;
    let lat_sram = base.expected_latency();
    rows.push(Row {
        experiment: "edram_tiles",
        variant: "SRAM 128 KB (paper)".into(),
        latency_ns: lat_sram,
        slowdown: slowdown(lat_sram, dram_ns),
        note: String::new(),
    });

    // Same capacity in eDRAM: the tile shrinks by the density ratio;
    // model it as an effectively smaller SRAM capacity for the
    // floorplan, with t_mem = 2 cycles.
    let density_ratio = MemTech::Edram.density_kb_per_mm2() / MemTech::Sram.density_kb_per_mm2();
    let equiv_kb = (128.0 / density_ratio).round() as u32; // area-equivalent SRAM
    let net = NetParams { t_mem: MemTech::Edram.cycle_ns().ceil(), ..NetParams::default() };
    let setup = EmulationSetup::build(
        TopologyKind::Clos,
        4096,
        equiv_kb.max(64),
        4095,
        net,
        &ChipTech::default(),
        &InterposerTech::default(),
    )?;
    let lat = setup.expected_latency();
    rows.push(Row {
        experiment: "edram_tiles",
        variant: format!("eDRAM 128 KB (footprint of {equiv_kb} KB SRAM)"),
        latency_ns: lat,
        slowdown: slowdown(lat, dram_ns),
        note: "2.4x density; +3-6 process steps (cost)".into(),
    });
    Ok(rows)
}

/// All ablations.
pub fn generate() -> Result<Vec<Row>> {
    let dram = SequentialMachine::with_measured_dram(1).dram_ns;
    let mut rows = Vec::new();
    rows.extend(route_open(dram)?);
    rows.extend(clock_scaling(dram)?);
    rows.extend(switch_degree(dram)?);
    rows.extend(edram_tiles(dram)?);
    Ok(rows)
}

/// Render the ablation table.
pub fn render(rows: &[Row]) -> String {
    let mut t = Table::new(&["experiment", "variant", "latency ns", "slowdown", "note"])
        .with_title("Ablations (4,096-tile folded Clos, full emulation, Dhrystone mix)");
    for r in rows {
        t.row(&[
            r.experiment.to_string(),
            r.variant.clone(),
            f(r.latency_ns, 1),
            format!("{}x", f(r.slowdown, 2)),
            r.note.clone(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_open_helps() {
        let rows = route_open(35.0).unwrap();
        assert!(rows[1].latency_ns < rows[0].latency_ns);
        // exactly 2 * t_open * (d+1) saved per access class; on average
        // the gap is 30-70 cycles.
        let gap = rows[0].latency_ns - rows[1].latency_ns;
        assert!(gap > 20.0 && gap < 80.0, "gap {gap}");
    }

    #[test]
    fn faster_network_clock_improves_factor() {
        let rows = clock_scaling(35.0).unwrap();
        // Wires re-pipeline into more cycles at higher clocks, so the
        // gain is sublinear but substantial.
        assert!(rows[1].latency_ns < rows[0].latency_ns * 0.75);
        assert!(rows[2].latency_ns < rows[1].latency_ns);
        assert!(rows[2].slowdown < rows[0].slowdown * 0.6);
        // §7.1: the DRAM cannot be clocked out of its latency — the
        // 4 GHz network emulation approaches parity.
        assert!(rows[2].slowdown < 1.6, "4 GHz slowdown {}", rows[2].slowdown);
    }

    #[test]
    fn degree64_trades_area_for_latency() {
        let rows = switch_degree(35.0).unwrap();
        // Fewer tiles cross chips (1,024-tile chips) but the die grows
        // ~4x and its wires lengthen — the net latency change is small
        // (within 30% either way), supporting the paper's degree-32
        // choice on economic grounds.
        let rel = rows[1].latency_ns / rows[0].latency_ns;
        assert!((0.7..=1.3).contains(&rel), "degree-64/degree-32 = {rel}");
        // ...and the note records the uneconomical chip.
        assert!(rows[1].note.contains("economical"));
    }

    #[test]
    fn edram_denser_but_slower_cells() {
        let rows = edram_tiles(35.0).unwrap();
        assert_eq!(rows.len(), 2);
        // Denser tiles shorten wires; t_mem grows by 1 cycle. Net
        // effect is small either way — assert within 15%.
        let rel = (rows[1].latency_ns - rows[0].latency_ns).abs() / rows[0].latency_ns;
        assert!(rel < 0.15, "rel {rel}");
    }
}
