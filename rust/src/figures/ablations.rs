//! Ablations of the paper's design choices — experiments the paper
//! discusses qualitatively but does not plot:
//!
//! * **Held-open routes** (§6.3): how much of the latency is the
//!   `t_open` route-setup cost?
//! * **Clock scaling** (§7.1): "an increase in clock speed for the
//!   parallel system would improve latency because the network would
//!   operate faster" — while the DRAM's intrinsic latency is fixed.
//! * **Switch degree** (§2): degree-64 switches halve the stage count
//!   sooner but quadruple the crossbar area.
//! * **eDRAM tiles** (§3.2/§5.0.3): the memory technology the paper
//!   rejected on manufacturing-cost grounds — denser tiles, slower
//!   access.
//!
//! Every variant is a [`DesignPoint`] perturbation of the caller's
//! [`Tech`] bundle, so `--set`/`--config` overrides flow into the
//! baselines as well as the ablated legs.

use anyhow::Result;

use crate::api::{DesignPoint, Mode, Report, Tech};
use crate::coordinator::ParallelSweep;
use crate::emulation::SequentialMachine;
use crate::netmodel::NetParams;
use crate::tech::{ChipTech, MemTech};
use crate::topology::ClosSpec;
use crate::util::table::{f, Table};

/// One ablation data point.
#[derive(Clone, Debug)]
pub struct Row {
    /// Experiment name.
    pub experiment: &'static str,
    /// Variant label.
    pub variant: String,
    /// Full-emulation mean access latency, ns.
    pub latency_ns: f64,
    /// Dhrystone-mix slowdown vs the DDR3 sequential machine.
    pub slowdown: f64,
    /// Note (area cost etc.).
    pub note: String,
}

fn slowdown(latency: f64, dram_ns: f64) -> f64 {
    crate::workload::predict_slowdown(&crate::workload::DHRYSTONE_MIX, latency, dram_ns)
}

/// Tile memory of the experiments' common design point (KB).
const MEM_KB: u32 = 128;

/// The experiments' common design point: the paper's largest system,
/// fully emulated.
fn headline(tech: &Tech) -> DesignPoint {
    DesignPoint::clos(4096).mem_kb(MEM_KB).k(4095).tech(tech)
}

/// Ablation 1: pay `t_open` per access vs hold routes open.
pub fn route_open(tech: &Tech, dram_ns: f64) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for (label, open) in [("closed routes (paper)", false), ("routes held open", true)] {
        let net = NetParams { route_open: open, ..tech.net };
        let lat = headline(tech).net(net).build()?.expected_latency();
        rows.push(Row {
            experiment: "route_open",
            variant: label.to_string(),
            latency_ns: lat,
            slowdown: slowdown(lat, dram_ns),
            note: if open { "requires per-client circuit reservation".into() } else { String::new() },
        });
    }
    Ok(rows)
}

/// Ablation 2: clock the parallel machine at 1/2/4 GHz while the DRAM
/// baseline keeps its intrinsic latency.
pub fn clock_scaling(tech: &Tech, dram_ns: f64) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for ghz in [1.0f64, 2.0, 4.0] {
        let chip = ChipTech { clock_ghz: ghz, ..tech.chip.clone() };
        let setup = headline(tech).chip(chip).build()?;
        // Cycles shrink in wall-clock as the clock rises; wire spans
        // re-pipeline to more cycles automatically via the floorplan.
        let lat_ns = setup.expected_latency() / ghz;
        rows.push(Row {
            experiment: "clock_scaling",
            variant: format!("{ghz} GHz network"),
            latency_ns: lat_ns,
            slowdown: slowdown(lat_ns, dram_ns),
            note: "DRAM latency is intrinsic (unchanged)".into(),
        });
    }
    Ok(rows)
}

/// Ablation 3: degree-64 switches (32 tiles/edge switch, 1,024
/// tiles/chip — exceeds the economical die, as §2 notes).
pub fn switch_degree(tech: &Tech, dram_ns: f64) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    // Baseline: degree-32 (the paper's design).
    let lat32 = headline(tech).build()?.expected_latency();
    rows.push(Row {
        experiment: "switch_degree",
        variant: "degree-32 (paper)".into(),
        latency_ns: lat32,
        slowdown: slowdown(lat32, dram_ns),
        note: "256-tile chips fit the economical band".into(),
    });

    // Degree-64: a crossbar is ~O(degree^2) area.
    let spec = ClosSpec { tiles: 4096, tiles_per_edge: 32, tiles_per_chip: 1024, degree: 64 };
    let chip64 = ChipTech { switch_area_mm2: 0.20, ..tech.chip.clone() };
    let area = crate::vlsi::ClosFloorplan::plan(&spec, MEM_KB, &chip64)?.area_mm2;
    let lat64 = headline(tech).clos_spec(spec).chip(chip64).build()?.expected_latency();
    rows.push(Row {
        experiment: "switch_degree",
        variant: "degree-64".into(),
        latency_ns: lat64,
        slowdown: slowdown(lat64, dram_ns),
        note: format!("chip {} mm^2 — far beyond the economical band", f(area, 0)),
    });
    Ok(rows)
}

/// Ablation 4: eDRAM tile memories — ~2.4x denser (smaller chips,
/// shorter wires) but 1.3 ns access (2 cycles) and costlier process.
pub fn edram_tiles(tech: &Tech, dram_ns: f64) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    let lat_sram = headline(tech).build()?.expected_latency();
    rows.push(Row {
        experiment: "edram_tiles",
        variant: format!("SRAM {MEM_KB} KB (paper)"),
        latency_ns: lat_sram,
        slowdown: slowdown(lat_sram, dram_ns),
        note: String::new(),
    });

    // Same capacity in eDRAM: the tile shrinks by the density ratio;
    // model it as an effectively smaller SRAM capacity for the
    // floorplan, with t_mem = 2 cycles.
    let density_ratio = MemTech::Edram.density_kb_per_mm2() / MemTech::Sram.density_kb_per_mm2();
    let equiv_kb = (MEM_KB as f64 / density_ratio).round() as u32; // area-equivalent SRAM
    let net = NetParams { t_mem: MemTech::Edram.cycle_ns().ceil(), ..tech.net };
    let lat =
        headline(tech).mem_kb(equiv_kb.max(64)).net(net).build()?.expected_latency();
    rows.push(Row {
        experiment: "edram_tiles",
        variant: format!("eDRAM {MEM_KB} KB (footprint of {equiv_kb} KB SRAM)"),
        latency_ns: lat,
        slowdown: slowdown(lat, dram_ns),
        note: "2.4x density; +3-6 process steps (cost)".into(),
    });
    Ok(rows)
}

/// All ablations on a shared sweep engine: the four experiments are
/// independent, so they fan out across the worker pool and reassemble
/// in the fixed experiment order (each experiment is deterministic, so
/// any `--jobs` is bit-identical).
pub fn generate_with(engine: &ParallelSweep) -> Result<Vec<Row>> {
    let dram = SequentialMachine::with_measured_dram(1).dram_ns;
    let tech = engine.tech();
    type Experiment = fn(&Tech, f64) -> Result<Vec<Row>>;
    let experiments: [Experiment; 4] =
        [route_open, clock_scaling, switch_degree, edram_tiles];
    let nested = engine.map(&experiments, |exp| exp(tech, dram))?;
    Ok(nested.into_iter().flatten().collect())
}

/// All ablations against a technology bundle (standalone: a fresh
/// engine).
pub fn generate(tech: &Tech) -> Result<Vec<Row>> {
    generate_with(&ParallelSweep::with_defaults(Mode::Exact, tech))
}

/// Full numeric output for the golden harness.
pub fn report(rows: &[Row]) -> Report {
    let mut rep = Report::new("ablations");
    for r in rows {
        rep.push(
            crate::api::Row::new(&format!("{}-{}", r.experiment, r.variant))
                .num("latency_ns", r.latency_ns)
                .num("slowdown", r.slowdown)
                .str("note", &r.note),
        );
    }
    rep
}

/// Render the ablation table.
pub fn render(rows: &[Row]) -> String {
    let mut t = Table::new(&["experiment", "variant", "latency ns", "slowdown", "note"])
        .with_title("Ablations (4,096-tile folded Clos, full emulation, Dhrystone mix)");
    for r in rows {
        t.row(&[
            r.experiment.to_string(),
            r.variant.clone(),
            f(r.latency_ns, 1),
            format!("{}x", f(r.slowdown, 2)),
            r.note.clone(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_open_helps() {
        let rows = route_open(&Tech::default(), 35.0).unwrap();
        assert!(rows[1].latency_ns < rows[0].latency_ns);
        // exactly 2 * t_open * (d+1) saved per access class; on average
        // the gap is 30-70 cycles.
        let gap = rows[0].latency_ns - rows[1].latency_ns;
        assert!(gap > 20.0 && gap < 80.0, "gap {gap}");
    }

    #[test]
    fn faster_network_clock_improves_factor() {
        let rows = clock_scaling(&Tech::default(), 35.0).unwrap();
        // Wires re-pipeline into more cycles at higher clocks, so the
        // gain is sublinear but substantial.
        assert!(rows[1].latency_ns < rows[0].latency_ns * 0.75);
        assert!(rows[2].latency_ns < rows[1].latency_ns);
        assert!(rows[2].slowdown < rows[0].slowdown * 0.6);
        // §7.1: the DRAM cannot be clocked out of its latency — the
        // 4 GHz network emulation approaches parity.
        assert!(rows[2].slowdown < 1.6, "4 GHz slowdown {}", rows[2].slowdown);
    }

    #[test]
    fn degree64_trades_area_for_latency() {
        let rows = switch_degree(&Tech::default(), 35.0).unwrap();
        // Fewer tiles cross chips (1,024-tile chips) but the die grows
        // ~4x and its wires lengthen — the net latency change is small
        // (within 30% either way), supporting the paper's degree-32
        // choice on economic grounds.
        let rel = rows[1].latency_ns / rows[0].latency_ns;
        assert!((0.7..=1.3).contains(&rel), "degree-64/degree-32 = {rel}");
        // ...and the note records the uneconomical chip.
        assert!(rows[1].note.contains("economical"));
    }

    #[test]
    fn edram_denser_but_slower_cells() {
        let rows = edram_tiles(&Tech::default(), 35.0).unwrap();
        assert_eq!(rows.len(), 2);
        // Denser tiles shorten wires; t_mem grows by 1 cycle. Net
        // effect is small either way — assert within 15%.
        let rel = (rows[1].latency_ns - rows[0].latency_ns).abs() / rows[0].latency_ns;
        assert!(rel < 0.15, "rel {rel}");
    }

    #[test]
    fn overrides_flow_into_the_baselines() {
        // The route_open baseline must honour a t_switch override (the
        // seed hard-coded NetParams::default() here).
        let doc = crate::config::Doc::parse("[net]\nt_switch = 4.0").unwrap();
        let base = route_open(&Tech::default(), 35.0).unwrap();
        let slow = route_open(&Tech::from_doc(&doc), 35.0).unwrap();
        assert!(slow[0].latency_ns > base[0].latency_ns);
    }
}
