//! The user-facing fault specification: what is broken, how badly, and
//! the seed every sampled draw derives from.

use anyhow::{bail, ensure, Result};

use crate::coordinator::point_seed;

/// Upper bound on [`FaultPlan::jitter_max`]: jitter is *bounded* by
/// contract (the DES charges `1..=jitter_max` extra cycles per degraded
/// traversal), and a bound above this is a configuration error, not a
/// model.
pub const JITTER_CEILING: u64 = 65_536;

/// A seed-deterministic fault model for one design point.
///
/// The plan is pure data: fractions, an explicit dead-tile list and a
/// seed. It is threaded through [`crate::api::DesignPoint::faults`],
/// validated by the builder (field-named errors), and materialised
/// against the built topology as a [`super::FaultMap`]. See the
/// [module docs](super) for the empty-plan oracle rule.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Explicitly dead tiles (their SRAM is lost; ranks remap around
    /// them). Must not contain duplicates or the primary (client) tile.
    pub dead_tiles: Vec<usize>,
    /// Fraction of tiles to *additionally* kill by sampling (rounded to
    /// `round(frac * tiles)` tiles, drawn from the non-client,
    /// non-explicitly-dead population). In `[0, 1)`.
    pub dead_tile_frac: f64,
    /// Fraction of undirected links that are degraded: each traversal
    /// of a degraded link costs `1..=jitter_max` extra cycles of
    /// seed-deterministic jitter. In `[0, 1]`.
    pub degraded_link_frac: f64,
    /// Bounded per-traversal jitter on degraded links, cycles. Must be
    /// `>= 1` when `degraded_link_frac > 0` and `<= JITTER_CEILING`.
    pub jitter_max: u64,
    /// Fraction of undirected links that are flaky: each traversal
    /// fails with probability `drop_prob` and is retried with capped
    /// exponential backoff (see `sim::network`). In `[0, 1]`.
    pub flaky_link_frac: f64,
    /// Per-traversal failure probability on flaky links. Must lie in
    /// `(0, 1)` when `flaky_link_frac > 0`.
    pub drop_prob: f64,
    /// Fraction of undirected links taken fully down by a failed switch
    /// port (a dead port kills its link in both directions — routing
    /// recomputes around it). In `[0, 1]`. Sampled failures that would
    /// disconnect the switch graph are healed (restored) in draw order.
    pub failed_port_frac: f64,
    /// Seed of every sampled draw (mixed with the design point's
    /// canonical key and a per-category stream constant).
    pub seed: u64,
}

impl FaultPlan {
    /// The empty plan: no faults. Bit-identical to not setting a plan
    /// at all (the empty-plan oracle rule).
    pub fn none() -> Self {
        Self::default()
    }

    /// True when the plan injects nothing — the machine is healthy and
    /// every fault path must be skipped entirely.
    pub fn is_empty(&self) -> bool {
        self.dead_tiles.is_empty()
            && self.dead_tile_frac == 0.0
            && self.degraded_link_frac == 0.0
            && self.flaky_link_frac == 0.0
            && self.failed_port_frac == 0.0
    }

    /// The one-knob plan the `faults` figure and `--fault-frac` sweep:
    /// fraction `f` of tiles dead, links degraded (jitter up to 4
    /// cycles) and links flaky (10 % drop), `f/2` of links port-failed.
    /// `f = 0` yields the empty plan.
    pub fn fraction(f: f64, seed: u64) -> Self {
        if f == 0.0 {
            return Self::none();
        }
        Self {
            dead_tiles: Vec::new(),
            dead_tile_frac: f,
            degraded_link_frac: f,
            jitter_max: 4,
            flaky_link_frac: f,
            drop_prob: 0.1,
            failed_port_frac: f / 2.0,
            seed,
        }
    }

    /// Canonical encoding of the plan — folded into figure cell seeds
    /// and cache keys, so two distinct plans never share a stream.
    /// Pure function of the plan's fields (f64 knobs by bit pattern).
    pub fn canonical_key(&self) -> u64 {
        let mut key = point_seed(0xFA17_0C0D_E000_0001, self.seed);
        for x in [
            self.dead_tile_frac.to_bits(),
            self.degraded_link_frac.to_bits(),
            self.jitter_max,
            self.flaky_link_frac.to_bits(),
            self.drop_prob.to_bits(),
            self.failed_port_frac.to_bits(),
        ] {
            key = point_seed(key, x);
        }
        for &t in &self.dead_tiles {
            key = point_seed(key, t as u64 ^ 0xDEAD);
        }
        key
    }

    /// Total dead tiles the plan produces on a `tiles`-tile system:
    /// the explicit list plus `round(dead_tile_frac * tiles)` sampled
    /// ones, clamped to the non-client population. Shared by builder
    /// validation (the capacity-degradation rule) and materialisation,
    /// so the two can never disagree.
    pub fn dead_tile_count(&self, tiles: usize) -> usize {
        let sampled = (self.dead_tile_frac * tiles as f64).round() as usize;
        let candidates = (tiles - 1).saturating_sub(self.dead_tiles.len());
        self.dead_tiles.len() + sampled.min(candidates)
    }

    /// Field-named validation against a concrete system: fraction
    /// ranges, jitter/drop consistency, dead-tile ids (in range, no
    /// duplicates, never the primary tile). The capacity-degradation
    /// check (`k` must fit the alive pool) lives in
    /// `DesignPoint::validate`, which knows `k`.
    pub fn validate(&self, tiles: usize, primary: usize) -> Result<()> {
        for (name, frac, half_open) in [
            ("dead_tile_frac", self.dead_tile_frac, true),
            ("degraded_link_frac", self.degraded_link_frac, false),
            ("flaky_link_frac", self.flaky_link_frac, false),
            ("failed_port_frac", self.failed_port_frac, false),
        ] {
            let ok = frac.is_finite()
                && frac >= 0.0
                && if half_open { frac < 1.0 } else { frac <= 1.0 };
            ensure!(
                ok,
                "field `fault.{name}`: fraction must lie in [0, 1{}, got {frac}",
                if half_open { ")" } else { "]" }
            );
        }
        if self.degraded_link_frac > 0.0 {
            ensure!(
                self.jitter_max >= 1,
                "field `fault.jitter_max`: degraded links need jitter_max >= 1, got {}",
                self.jitter_max
            );
        }
        ensure!(
            self.jitter_max <= JITTER_CEILING,
            "field `fault.jitter_max`: jitter is bounded by {JITTER_CEILING}, got {}",
            self.jitter_max
        );
        if self.flaky_link_frac > 0.0 {
            ensure!(
                self.drop_prob.is_finite() && self.drop_prob > 0.0 && self.drop_prob < 1.0,
                "field `fault.drop_prob`: flaky links need a drop probability in (0, 1), got {}",
                self.drop_prob
            );
        } else {
            ensure!(
                self.drop_prob.is_finite() && (0.0..1.0).contains(&self.drop_prob),
                "field `fault.drop_prob`: must lie in [0, 1), got {}",
                self.drop_prob
            );
        }
        let mut seen = std::collections::HashSet::new();
        for &t in &self.dead_tiles {
            if t >= tiles {
                bail!("field `fault.dead_tiles`: tile {t} out of range (tiles = {tiles})");
            }
            if t == primary {
                bail!(
                    "field `fault.dead_tiles`: tile {t} is the primary (client) tile — \
                     a plan may not kill the client"
                );
            }
            if !seen.insert(t) {
                bail!("field `fault.dead_tiles`: duplicate dead-tile id {t}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_empty() {
        assert!(FaultPlan::none().is_empty());
        assert!(FaultPlan::default().is_empty());
        assert!(FaultPlan::fraction(0.0, 99).is_empty());
        assert!(!FaultPlan::fraction(0.05, 99).is_empty());
        assert!(!FaultPlan { dead_tiles: vec![3], ..FaultPlan::none() }.is_empty());
        // A plan with only a seed set injects nothing.
        assert!(FaultPlan { seed: 0xBEEF, ..FaultPlan::none() }.is_empty());
    }

    #[test]
    fn canonical_key_separates_plans() {
        let a = FaultPlan::fraction(0.05, 1);
        assert_eq!(a.canonical_key(), a.clone().canonical_key());
        for b in [
            FaultPlan::fraction(0.06, 1),
            FaultPlan::fraction(0.05, 2),
            FaultPlan { jitter_max: 5, ..a.clone() },
            FaultPlan { dead_tiles: vec![7], ..a.clone() },
        ] {
            assert_ne!(a.canonical_key(), b.canonical_key(), "{b:?}");
        }
    }

    #[test]
    fn dead_tile_count_clamps_to_population() {
        let p = FaultPlan { dead_tile_frac: 0.1, ..FaultPlan::none() };
        assert_eq!(p.dead_tile_count(1024), 102); // round(102.4)
        let p = FaultPlan { dead_tiles: vec![1, 2], dead_tile_frac: 0.9, ..FaultPlan::none() };
        // 8 tiles: round(7.2)=7 sampled, but only 8-1-2=5 candidates.
        assert_eq!(p.dead_tile_count(8), 7);
    }

    #[test]
    fn validation_names_every_offending_field() {
        for (plan, field) in [
            (FaultPlan { dead_tile_frac: 1.5, ..FaultPlan::none() }, "`fault.dead_tile_frac`"),
            (FaultPlan { dead_tile_frac: -0.1, ..FaultPlan::none() }, "`fault.dead_tile_frac`"),
            (
                FaultPlan { degraded_link_frac: 2.0, ..FaultPlan::none() },
                "`fault.degraded_link_frac`",
            ),
            (
                FaultPlan { degraded_link_frac: f64::NAN, ..FaultPlan::none() },
                "`fault.degraded_link_frac`",
            ),
            (FaultPlan { flaky_link_frac: -1.0, ..FaultPlan::none() }, "`fault.flaky_link_frac`"),
            (
                FaultPlan { failed_port_frac: 1.01, ..FaultPlan::none() },
                "`fault.failed_port_frac`",
            ),
            (
                FaultPlan { degraded_link_frac: 0.1, jitter_max: 0, ..FaultPlan::none() },
                "`fault.jitter_max`",
            ),
            (
                FaultPlan { jitter_max: JITTER_CEILING + 1, ..FaultPlan::none() },
                "`fault.jitter_max`",
            ),
            (
                FaultPlan { flaky_link_frac: 0.1, drop_prob: 0.0, ..FaultPlan::none() },
                "`fault.drop_prob`",
            ),
            (
                FaultPlan { flaky_link_frac: 0.1, drop_prob: 1.0, ..FaultPlan::none() },
                "`fault.drop_prob`",
            ),
            (FaultPlan { drop_prob: 1.0, ..FaultPlan::none() }, "`fault.drop_prob`"),
            (FaultPlan { dead_tiles: vec![256], ..FaultPlan::none() }, "`fault.dead_tiles`"),
            (FaultPlan { dead_tiles: vec![3, 3], ..FaultPlan::none() }, "`fault.dead_tiles`"),
            (FaultPlan { dead_tiles: vec![0], ..FaultPlan::none() }, "`fault.dead_tiles`"),
        ] {
            let err = plan.validate(256, 0).unwrap_err().to_string();
            assert!(err.contains(field), "error `{err}` does not name {field}");
        }
        // Killing the primary names the client explicitly.
        let err = FaultPlan { dead_tiles: vec![0], ..FaultPlan::none() }
            .validate(256, 0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("primary"), "{err}");
        // A valid plan passes.
        FaultPlan::fraction(0.05, 7).validate(256, 0).unwrap();
        FaultPlan { dead_tiles: vec![1, 5, 9], ..FaultPlan::none() }.validate(256, 0).unwrap();
    }
}
