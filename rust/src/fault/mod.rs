//! Fault injection & degradation: a seed-deterministic fault model for
//! otherwise-healthy design points.
//!
//! Production machines lose tiles, links and switch ports; the paper
//! models none of that. This module supplies the missing layer as a
//! two-stage pipeline:
//!
//! * [`FaultPlan`] (in [`plan`]) — the user-facing *specification*: an
//!   explicit dead-tile list plus sampled fault fractions (dead tiles,
//!   degraded links with bounded latency jitter, flaky links with a
//!   per-traversal drop probability, failed switch ports) and the plan
//!   seed every draw derives from. A plan is data; it names no concrete
//!   link until it meets a topology.
//! * [`FaultMap`] (in [`map`]) — the *materialisation* of a plan
//!   against one built topology: the sorted dead-tile set and a
//!   per-directed-port [`PortFault`] arena indexed by the
//!   [`crate::topology::RoutingTable`] CSR port ids. Every draw comes
//!   from [`crate::coordinator::point_seed`] streams keyed by the plan
//!   seed, the design point's canonical key and a per-category stream
//!   constant — a pure function of identity, never of scheduling — so
//!   any `--jobs` count materialises bit-identical faults.
//!
//! [`FaultState`] bundles the plan, its materialised map and the
//! dead-tile-aware rank remap ([`crate::emulation::AddressMap::remap_ranks`])
//! inside an [`crate::emulation::EmulationSetup`].
//!
//! # The empty-plan oracle rule
//!
//! An empty plan ([`FaultPlan::is_empty`]) must leave **every** path —
//! routing tables, DES timing, contention summaries, figure bits —
//! bit-identical to the healthy machine. The implementation guarantees
//! this by construction: `DesignPoint::build` skips materialisation
//! entirely for an empty plan (`setup.fault == None`), and every fault
//! branch in the DES is guarded by "is there a non-default port
//! fault?". New fault kinds MUST keep this shape: default-valued knobs
//! mean "not present", and the `tests/fault_determinism.rs` empty-plan
//! suite must keep passing unchanged.
//!
//! # Typed failure, never panics
//!
//! Hand-built plans can sever the network or kill the memory pool;
//! both surface as typed errors: [`FaultError::Unreachable`] from the
//! DES walk, and field-named `DesignPoint` validation errors for plans
//! that kill the primary tile or leave fewer than `k` alive tiles
//! (the capacity-degradation rule). Sampled plans are *healed*: port
//! failures that would disconnect the switch graph are restored in
//! draw order, so `figures::faults` and `figures --all` never trip the
//! error path (tests exercise it with hand-built maps instead).

pub mod map;
pub mod plan;

pub use map::{FaultError, FaultMap, FaultState, PortFault};
pub use plan::FaultPlan;

/// Stream constant separating the DES's per-scenario fault RNG (jitter
/// and flaky-link draws) from the address-stream seed of the same
/// scenario: the fault stream is `point_seed(scenario_seed, DES_STREAM)`.
pub const DES_STREAM: u64 = 0xFA17_0DE5;
