//! Materialising a [`FaultPlan`] against one built topology: the
//! concrete dead tiles and per-directed-port fault states the routing
//! layer and the DES consume.

use anyhow::Result;

use super::plan::FaultPlan;
use crate::coordinator::point_seed;
use crate::emulation::AddressMap;
use crate::topology::graph::{port_offsets, Graph, NodeId};
use crate::topology::Topology;
use crate::util::rng::Rng;

/// Per-category stream constants: each fault category draws from its
/// own `point_seed(plan_key ^ design_key, STREAM)` generator, so adding
/// a category never perturbs another's draws.
const STREAM_DEAD: u64 = 0xFA17_0001;
const STREAM_DEGRADED: u64 = 0xFA17_0002;
const STREAM_FLAKY: u64 = 0xFA17_0003;
const STREAM_PORTS: u64 = 0xFA17_0004;

/// Fault state of one *directed* switch port (the unit of the DES's
/// per-port arena). Default = healthy.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PortFault {
    /// The port (and hence its link) is down: routing avoids it, and a
    /// message that would need it finds the destination unreachable.
    pub failed: bool,
    /// Degraded link: each traversal costs `1..=jitter_max` extra
    /// cycles of seed-deterministic jitter (0 = healthy).
    pub jitter_max: u64,
    /// Flaky link: each traversal fails with this probability and is
    /// retried with capped exponential backoff (0.0 = reliable).
    pub drop_prob: f64,
}

impl PortFault {
    /// True when the port carries any fault at all.
    pub fn is_faulty(&self) -> bool {
        self.failed || self.jitter_max > 0 || self.drop_prob > 0.0
    }
}

/// Typed failure of a fault-aware network operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultError {
    /// No route exists between two switches under the active fault
    /// plan (every connecting port is failed).
    Unreachable {
        /// Source switch.
        from: usize,
        /// Destination switch.
        to: usize,
    },
}

impl std::fmt::Display for FaultError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaultError::Unreachable { from, to } => write!(
                f,
                "switch {to} is unreachable from switch {from} under the active fault plan"
            ),
        }
    }
}

impl std::error::Error for FaultError {}

/// A [`FaultPlan`] materialised against one topology: concrete dead
/// tiles and a per-directed-port fault arena.
///
/// Determinism contract: `materialise` is a pure function of
/// `(plan, topology, client, design_key)` — every draw comes from a
/// canonical [`point_seed`] stream, so rebuilding the same design
/// point yields bit-identical faults at any `--jobs` count.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultMap {
    /// Dead tiles (explicit + sampled), sorted ascending. Never
    /// contains the client tile.
    pub dead_tiles: Vec<usize>,
    /// Fault state per directed port, indexed by the
    /// [`crate::topology::RoutingTable`] CSR port id. `failed` is set
    /// symmetrically (a dead port takes its link down both ways), so
    /// routing over the surviving links stays well-defined.
    pub ports: Vec<PortFault>,
    /// Undirected links degraded (jitter).
    pub degraded_links: usize,
    /// Undirected links flaky (drop + retry).
    pub flaky_links: usize,
    /// Undirected links fully down (failed ports), after healing.
    pub failed_links: usize,
    /// Sampled port failures restored because they would have
    /// disconnected the switch graph (the documented heal rule:
    /// sampled plans never partition the machine; only hand-built maps
    /// can produce [`FaultError::Unreachable`]).
    pub healed_links: usize,
}

impl FaultMap {
    /// Materialise a plan against a topology. `design_key` is the
    /// design point's canonical encoding (it decorrelates the same
    /// plan across different systems); `client` is the primary tile,
    /// excluded from dead-tile sampling.
    pub fn materialise(
        plan: &FaultPlan,
        topo: &Topology,
        client: usize,
        design_key: u64,
    ) -> Self {
        let g = topo.graph();
        let offsets = port_offsets(g);
        let num_ports = *offsets.last().unwrap_or(&0) as usize;
        let base = plan.canonical_key() ^ design_key;

        // Dead tiles: the explicit list plus a sampled complement,
        // drawn from the non-client, non-explicit population by a
        // partial Fisher-Yates over the ascending candidate list.
        let tiles = g.num_tiles();
        let mut dead_tiles = plan.dead_tiles.clone();
        let extra = plan.dead_tile_count(tiles) - dead_tiles.len();
        if extra > 0 {
            let explicit: std::collections::HashSet<usize> =
                dead_tiles.iter().copied().collect();
            let mut candidates: Vec<usize> =
                (0..tiles).filter(|&t| t != client && !explicit.contains(&t)).collect();
            let mut rng = Rng::new(point_seed(base, STREAM_DEAD));
            for i in 0..extra {
                let j = i + rng.below((candidates.len() - i) as u64) as usize;
                candidates.swap(i, j);
            }
            dead_tiles.extend_from_slice(&candidates[..extra]);
        }
        dead_tiles.sort_unstable();

        // Link faults: walk the undirected links in canonical order
        // (ascending by lower endpoint, then adjacency index) and draw
        // each category from its own stream. Degraded/flaky states and
        // port failures are applied to BOTH directed ports of a link.
        let links = undirected_links(g, &offsets);
        let mut ports = vec![PortFault::default(); num_ports];
        let mut degraded_links = 0usize;
        let mut flaky_links = 0usize;
        if plan.degraded_link_frac > 0.0 {
            let mut rng = Rng::new(point_seed(base, STREAM_DEGRADED));
            for &(p, q) in &links {
                if rng.chance(plan.degraded_link_frac) {
                    ports[p].jitter_max = plan.jitter_max;
                    ports[q].jitter_max = plan.jitter_max;
                    degraded_links += 1;
                }
            }
        }
        if plan.flaky_link_frac > 0.0 {
            let mut rng = Rng::new(point_seed(base, STREAM_FLAKY));
            for &(p, q) in &links {
                if rng.chance(plan.flaky_link_frac) {
                    ports[p].drop_prob = plan.drop_prob;
                    ports[q].drop_prob = plan.drop_prob;
                    flaky_links += 1;
                }
            }
        }

        // Failed ports, with the connectivity heal rule: a sampled
        // failure that would shrink the switch graph's reachable set is
        // restored (in draw order), so sampled plans never partition
        // the client from the memory pool.
        let mut failed_links = 0usize;
        let mut healed_links = 0usize;
        if plan.failed_port_frac > 0.0 && !links.is_empty() {
            let mut rng = Rng::new(point_seed(base, STREAM_PORTS));
            let baseline = reachable_count(g, &offsets, &ports);
            for &(p, q) in &links {
                if !rng.chance(plan.failed_port_frac) {
                    continue;
                }
                if ports[p].failed {
                    continue; // already down (parallel link share)
                }
                ports[p].failed = true;
                ports[q].failed = true;
                if reachable_count(g, &offsets, &ports) == baseline {
                    failed_links += 1;
                } else {
                    ports[p].failed = false;
                    ports[q].failed = false;
                    healed_links += 1;
                }
            }
        }

        Self { dead_tiles, ports, degraded_links, flaky_links, failed_links, healed_links }
    }

    /// True when any directed port carries a fault (the DES's guard:
    /// false means the walk must take the exact healthy path).
    pub fn has_port_faults(&self) -> bool {
        self.ports.iter().any(|p| p.is_faulty())
    }

    /// The per-directed-port failed mask routing builds avoid.
    pub fn failed_ports(&self) -> Vec<bool> {
        self.ports.iter().map(|p| p.failed).collect()
    }
}

/// A plan bundled with its materialisation and the dead-tile-aware
/// rank placement — the fault field of an
/// [`crate::emulation::EmulationSetup`].
#[derive(Clone, Debug, PartialEq)]
pub struct FaultState {
    /// The specification.
    pub plan: FaultPlan,
    /// Its materialisation against this setup's topology.
    pub map: FaultMap,
    /// Rank -> physical tile, remapped off the dead tiles
    /// ([`AddressMap::remap_ranks`]); identical to the healthy ring
    /// when no tile is dead.
    pub rank_tile: Vec<usize>,
}

impl FaultState {
    /// Materialise `plan` for a built topology + address map. Errors
    /// only on the capacity-degradation rule (dead tiles leaving fewer
    /// than `k` alive tiles) — a backstop; `DesignPoint::validate`
    /// reports the same condition with a field-named error first.
    pub fn materialise(
        plan: &FaultPlan,
        topo: &Topology,
        map: &AddressMap,
        design_key: u64,
    ) -> Result<Self> {
        let fmap = FaultMap::materialise(plan, topo, map.client, design_key);
        let rank_tile = map.remap_ranks(&fmap.dead_tiles)?;
        Ok(Self { plan: plan.clone(), map: fmap, rank_tile })
    }
}

/// Canonical undirected-link enumeration as `(port_uv, port_vu)` CSR
/// port-id pairs: ascending by lower endpoint `u`, then by `u`'s
/// adjacency index. Multigraph-safe: the `c`-th adjacency entry of `u`
/// targeting `v` pairs with the `c`-th entry of `v` targeting `u`
/// (valid because `Graph::add_link` pushes both directions together).
fn undirected_links(g: &Graph, offsets: &[u32]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for u in 0..g.num_switches() {
        let mut occurrence = std::collections::HashMap::new();
        for (e, &(v, _)) in g.neighbours(NodeId(u)).iter().enumerate() {
            let c = occurrence.entry(v.0).or_insert(0usize);
            let this_c = *c;
            *c += 1;
            if v.0 <= u {
                continue; // counted from the lower endpoint (no self loops exist)
            }
            let e2 = g
                .neighbours(v)
                .iter()
                .enumerate()
                .filter(|&(_, &(w, _))| w.0 == u)
                .nth(this_c)
                .map(|(i, _)| i)
                .expect("undirected multigraph: reverse entry exists");
            out.push((offsets[u] as usize + e, offsets[v.0] as usize + e2));
        }
    }
    out
}

/// Switches reachable from switch 0 over non-failed links.
fn reachable_count(g: &Graph, offsets: &[u32], ports: &[PortFault]) -> usize {
    let n = g.num_switches();
    if n == 0 {
        return 0;
    }
    let mut seen = vec![false; n];
    let mut stack = vec![0usize];
    seen[0] = true;
    let mut count = 1;
    while let Some(u) = stack.pop() {
        for (e, &(v, _)) in g.neighbours(NodeId(u)).iter().enumerate() {
            if !ports[offsets[u] as usize + e].failed && !seen[v.0] {
                seen[v.0] = true;
                count += 1;
                stack.push(v.0);
            }
        }
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{ClosSpec, FoldedClos, RoutingTable};

    fn clos(tiles: usize) -> Topology {
        Topology::Clos(FoldedClos::build(ClosSpec::with_tiles(tiles)).unwrap())
    }

    fn mesh(tiles: usize) -> Topology {
        use crate::topology::{Mesh2D, MeshSpec};
        Topology::Mesh(Mesh2D::build(MeshSpec::with_tiles(tiles)).unwrap())
    }

    #[test]
    fn materialise_is_deterministic() {
        let topo = clos(1024);
        let plan = FaultPlan::fraction(0.08, 42);
        let a = FaultMap::materialise(&plan, &topo, 0, 0xD15C0);
        let b = FaultMap::materialise(&plan, &topo, 0, 0xD15C0);
        assert_eq!(a, b);
        // A different design key draws different faults.
        let c = FaultMap::materialise(&plan, &topo, 0, 0xD15C1);
        assert_ne!(a, c);
    }

    #[test]
    fn dead_tiles_skip_client_and_hit_the_count() {
        for topo in [clos(256), mesh(256)] {
            let plan = FaultPlan {
                dead_tiles: vec![7, 19],
                dead_tile_frac: 0.1,
                ..FaultPlan::none()
            };
            let m = FaultMap::materialise(&plan, &topo, 5, 1);
            assert_eq!(m.dead_tiles.len(), plan.dead_tile_count(256));
            assert!(m.dead_tiles.contains(&7) && m.dead_tiles.contains(&19));
            assert!(!m.dead_tiles.contains(&5), "client sampled dead");
            let mut sorted = m.dead_tiles.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted, m.dead_tiles, "sorted and duplicate-free");
        }
    }

    #[test]
    fn link_faults_are_symmetric() {
        let topo = clos(1024);
        let g = topo.graph();
        let offsets = port_offsets(g);
        let plan = FaultPlan::fraction(0.10, 3);
        let m = FaultMap::materialise(&plan, &topo, 0, 9);
        assert!(m.degraded_links > 0 && m.flaky_links > 0, "{m:?}");
        for &(p, q) in &undirected_links(g, &offsets) {
            assert_eq!(m.ports[p].failed, m.ports[q].failed);
            assert_eq!(m.ports[p].jitter_max, m.ports[q].jitter_max);
            assert_eq!(m.ports[p].drop_prob.to_bits(), m.ports[q].drop_prob.to_bits());
        }
    }

    #[test]
    fn sampled_port_failures_never_disconnect() {
        // The heal rule: after materialisation the whole switch graph
        // stays mutually reachable through the fault-avoiding table.
        for topo in [clos(1024), mesh(256)] {
            let plan = FaultPlan {
                failed_port_frac: 0.25, // aggressive, to force healing
                ..FaultPlan::none()
            };
            let m = FaultMap::materialise(&plan, &topo, 0, 4);
            assert!(m.failed_links > 0, "nothing failed at 25%");
            let rt = RoutingTable::build_avoiding(topo.graph(), &m.failed_ports());
            let g = topo.graph();
            for s in 0..g.num_switches() {
                assert!(
                    rt.walk_distance(g, NodeId(0), NodeId(s)).is_some(),
                    "switch {s} unreachable after sampled faults"
                );
            }
        }
    }

    #[test]
    fn empty_plan_materialises_to_nothing() {
        let topo = clos(256);
        let m = FaultMap::materialise(&FaultPlan::none(), &topo, 0, 1);
        assert!(m.dead_tiles.is_empty());
        assert!(!m.has_port_faults());
        assert_eq!(m.degraded_links + m.flaky_links + m.failed_links, 0);
    }

    #[test]
    fn undirected_links_pair_every_directed_port() {
        for topo in [clos(1024), mesh(256)] {
            let g = topo.graph();
            let offsets = port_offsets(g);
            let links = undirected_links(g, &offsets);
            let num_ports = *offsets.last().unwrap() as usize;
            assert_eq!(links.len() * 2, num_ports, "{}", topo.name());
            let mut seen = vec![false; num_ports];
            for &(p, q) in &links {
                assert_ne!(p, q);
                for x in [p, q] {
                    assert!(!seen[x], "port {x} paired twice");
                    seen[x] = true;
                }
            }
            assert!(seen.iter().all(|&s| s));
        }
    }

    #[test]
    fn fault_error_displays_switches() {
        let e = FaultError::Unreachable { from: 3, to: 9 };
        let s = e.to_string();
        assert!(s.contains('3') && s.contains('9') && s.contains("unreachable"));
        let _: &dyn std::error::Error = &e;
    }

    #[test]
    fn state_capacity_rule_is_a_typed_error() {
        let topo = clos(256);
        let map = AddressMap::new(12, 255, 0, 256);
        let plan = FaultPlan { dead_tiles: vec![9], ..FaultPlan::none() };
        let design_key = 0x51;
        let err =
            FaultState::materialise(&plan, &topo, &map, design_key).unwrap_err().to_string();
        assert!(err.contains("alive"), "{err}");
        // With head room the remap simply skips the dead tile.
        let map = AddressMap::new(12, 200, 0, 256);
        let st = FaultState::materialise(&plan, &topo, &map, design_key).unwrap();
        assert_eq!(st.rank_tile.len(), 200);
        assert!(!st.rank_tile.contains(&9));
        assert!(!st.rank_tile.contains(&0));
    }
}
