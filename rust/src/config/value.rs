//! A TOML-subset parser (offline image: no serde/toml crates).
//!
//! Supported syntax — everything the memclos config files need:
//!
//! ```toml
//! # comment
//! [section.subsection]
//! int_key = 42
//! float_key = 3.5
//! bool_key = true
//! string_key = "text"
//! array_key = [1, 2, 3]
//! ```
//!
//! Keys are flattened to dotted paths (`section.subsection.int_key`).

use std::collections::BTreeMap;
use std::fmt;

use thiserror::Error;

/// A parsed configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// Boolean literal.
    Bool(bool),
    /// Quoted string.
    Str(String),
    /// Homogeneous or heterogeneous array.
    Array(Vec<Value>),
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Int(v) => write!(f, "{v}"),
            Value::Float(v) => write!(f, "{v}"),
            Value::Bool(v) => write!(f, "{v}"),
            Value::Str(v) => write!(f, "\"{v}\""),
            Value::Array(vs) => {
                write!(f, "[")?;
                for (i, v) in vs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Parse errors with line information.
#[derive(Debug, Error)]
pub enum ParseError {
    /// Malformed line (no `=`, bad section header, ...).
    #[error("line {line}: {msg}")]
    Syntax {
        /// 1-based line number.
        line: usize,
        /// Description.
        msg: String,
    },
}

/// A flat dotted-key -> value map.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Doc {
    map: BTreeMap<String, Value>,
}

impl Doc {
    /// Empty document.
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse a TOML-subset document.
    pub fn parse(text: &str) -> Result<Self, ParseError> {
        let mut doc = Doc::new();
        let mut section = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let inner = rest.strip_suffix(']').ok_or_else(|| ParseError::Syntax {
                    line: lineno,
                    msg: "unterminated section header".into(),
                })?;
                let name = inner.trim();
                if name.is_empty() || !name.chars().all(is_key_char_or_dot) {
                    return Err(ParseError::Syntax {
                        line: lineno,
                        msg: format!("bad section name `{name}`"),
                    });
                }
                section = name.to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| ParseError::Syntax {
                line: lineno,
                msg: "expected `key = value`".into(),
            })?;
            let key = line[..eq].trim();
            if key.is_empty() || !key.chars().all(is_key_char) {
                return Err(ParseError::Syntax { line: lineno, msg: format!("bad key `{key}`") });
            }
            let value = parse_value(line[eq + 1..].trim(), lineno)?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            doc.map.insert(full, value);
        }
        Ok(doc)
    }

    /// Insert / override a value at a dotted path.
    pub fn set(&mut self, key: &str, value: Value) {
        self.map.insert(key.to_string(), value);
    }

    /// Apply a `key=value` override (CLI `--set`); the value is parsed
    /// with the same literal grammar as the file format.
    pub fn set_str(&mut self, assignment: &str) -> Result<(), ParseError> {
        let eq = assignment.find('=').ok_or_else(|| ParseError::Syntax {
            line: 0,
            msg: format!("override `{assignment}` is not key=value"),
        })?;
        let key = assignment[..eq].trim().to_string();
        let value = parse_value(assignment[eq + 1..].trim(), 0)?;
        self.map.insert(key, value);
        Ok(())
    }

    /// Raw lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    /// Integer at `key`, or `default`.
    pub fn int(&self, key: &str, default: i64) -> i64 {
        match self.map.get(key) {
            Some(Value::Int(v)) => *v,
            Some(Value::Float(v)) => *v as i64,
            _ => default,
        }
    }

    /// Float at `key`, or `default` (ints coerce).
    pub fn float(&self, key: &str, default: f64) -> f64 {
        match self.map.get(key) {
            Some(Value::Float(v)) => *v,
            Some(Value::Int(v)) => *v as f64,
            _ => default,
        }
    }

    /// Bool at `key`, or `default`.
    pub fn bool(&self, key: &str, default: bool) -> bool {
        match self.map.get(key) {
            Some(Value::Bool(v)) => *v,
            _ => default,
        }
    }

    /// String at `key`, or `default`.
    pub fn str(&self, key: &str, default: &str) -> String {
        match self.map.get(key) {
            Some(Value::Str(v)) => v.clone(),
            _ => default.to_string(),
        }
    }

    /// Integer array at `key`, or `default`.
    pub fn ints(&self, key: &str, default: &[i64]) -> Vec<i64> {
        match self.map.get(key) {
            Some(Value::Array(vs)) => vs
                .iter()
                .filter_map(|v| match v {
                    Value::Int(i) => Some(*i),
                    Value::Float(f) => Some(*f as i64),
                    _ => None,
                })
                .collect(),
            Some(Value::Int(i)) => vec![*i],
            _ => default.to_vec(),
        }
    }

    /// All keys (sorted).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when no entries.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

fn is_key_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_' || c == '-'
}

fn is_key_char_or_dot(c: char) -> bool {
    is_key_char(c) || c == '.'
}

fn strip_comment(line: &str) -> &str {
    // A `#` inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, line: usize) -> Result<Value, ParseError> {
    let t = text.trim();
    if t.is_empty() {
        return Err(ParseError::Syntax { line, msg: "empty value".into() });
    }
    if let Some(rest) = t.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or_else(|| ParseError::Syntax {
            line,
            msg: "unterminated array".into(),
        })?;
        let mut items = Vec::new();
        let inner = inner.trim();
        if !inner.is_empty() {
            for part in inner.split(',') {
                let p = part.trim();
                if p.is_empty() {
                    continue; // tolerate trailing comma
                }
                items.push(parse_value(p, line)?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Some(rest) = t.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or_else(|| ParseError::Syntax {
            line,
            msg: "unterminated string".into(),
        })?;
        return Ok(Value::Str(inner.to_string()));
    }
    match t {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = t.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = t.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    // Bare words are accepted as strings (ergonomic for --set topo=mesh).
    if t.chars().all(is_key_char) {
        return Ok(Value::Str(t.to_string()));
    }
    Err(ParseError::Syntax { line, msg: format!("cannot parse value `{t}`") })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = Doc::parse(
            r#"
            # top comment
            top = 1
            [system]
            tiles = 1024            # inline comment
            topo = "clos"
            [system.net]
            t_switch = 2.0
            open = false
            caps = [64, 128, 256]
            "#,
        )
        .unwrap();
        assert_eq!(doc.int("top", 0), 1);
        assert_eq!(doc.int("system.tiles", 0), 1024);
        assert_eq!(doc.str("system.topo", ""), "clos");
        assert_eq!(doc.float("system.net.t_switch", 0.0), 2.0);
        assert!(!doc.bool("system.net.open", true));
        assert_eq!(doc.ints("system.net.caps", &[]), vec![64, 128, 256]);
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let doc = Doc::parse("").unwrap();
        assert_eq!(doc.int("nope", 7), 7);
        assert_eq!(doc.float("nope", 1.5), 1.5);
        assert_eq!(doc.str("nope", "d"), "d");
        assert!(doc.is_empty());
    }

    #[test]
    fn int_coerces_to_float() {
        let doc = Doc::parse("x = 3").unwrap();
        assert_eq!(doc.float("x", 0.0), 3.0);
    }

    #[test]
    fn set_str_overrides() {
        let mut doc = Doc::parse("a = 1").unwrap();
        doc.set_str("a=2").unwrap();
        doc.set_str("b.c=clos").unwrap();
        assert_eq!(doc.int("a", 0), 2);
        assert_eq!(doc.str("b.c", ""), "clos");
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = Doc::parse(r##"s = "a#b""##).unwrap();
        assert_eq!(doc.str("s", ""), "a#b");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Doc::parse("ok = 1\nbroken").unwrap_err();
        assert!(err.to_string().contains("line 2"));
    }

    #[test]
    fn rejects_bad_section() {
        assert!(Doc::parse("[bad section]").is_err());
        assert!(Doc::parse("[unterminated").is_err());
    }

    #[test]
    fn empty_and_trailing_comma_arrays() {
        let doc = Doc::parse("a = []\nb = [1, 2,]").unwrap();
        assert_eq!(doc.ints("a", &[9]), Vec::<i64>::new());
        assert_eq!(doc.ints("b", &[]), vec![1, 2]);
    }
}
