//! Configuration system: a TOML-subset document ([`Doc`]) plus typed
//! extraction helpers used by every subsystem's `from_doc` constructor.
//!
//! Precedence (lowest to highest): built-in defaults (the paper's
//! parameters, Tables 1–5) → config file (`--config path`) → CLI
//! overrides (`--set key=value`).

mod value;

use std::path::Path;

use anyhow::{Context, Result};

pub use value::{Doc, ParseError, Value};

/// Load a config file and apply `--set` overrides on top.
pub fn load(path: Option<&Path>, overrides: &[String]) -> Result<Doc> {
    let mut doc = match path {
        Some(p) => {
            let text = std::fs::read_to_string(p)
                .with_context(|| format!("reading config {}", p.display()))?;
            Doc::parse(&text).with_context(|| format!("parsing config {}", p.display()))?
        }
        None => Doc::new(),
    };
    for ov in overrides {
        doc.set_str(ov).with_context(|| format!("applying override `{ov}`"))?;
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_defaults_when_no_file() {
        let doc = load(None, &[]).unwrap();
        assert!(doc.is_empty());
    }

    #[test]
    fn overrides_apply_without_file() {
        let doc = load(None, &["a.b=3".to_string()]).unwrap();
        assert_eq!(doc.int("a.b", 0), 3);
    }

    #[test]
    fn bad_override_is_error() {
        assert!(load(None, &["no-equals".to_string()]).is_err());
    }
}
