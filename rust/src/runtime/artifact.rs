//! Artifact loading: HLO text file -> PJRT executable.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Default artifact directory: `$MEMCLOS_ARTIFACTS` or `artifacts/` under
/// the crate root (falling back to the current directory at runtime).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("MEMCLOS_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // When run via cargo (tests, benches, examples) the manifest dir is
    // the repo root; otherwise fall back to ./artifacts.
    if let Ok(dir) = std::env::var("CARGO_MANIFEST_DIR") {
        return Path::new(&dir).join("artifacts");
    }
    PathBuf::from("artifacts")
}

/// One AOT-compiled computation: HLO text loaded from disk, compiled on a
/// PJRT client, ready to execute.
pub struct Artifact {
    name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Load `<dir>/<name>.hlo.txt` and compile it on `client`.
    pub fn load(client: &xla::PjRtClient, dir: &Path, name: &str) -> Result<Self> {
        let path = dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("loading HLO text from {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling artifact `{name}`"))?;
        Ok(Self { name: name.to_string(), exe })
    }

    /// Artifact name (file stem).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with the given literals; returns the elements of the
    /// result tuple (aot.py lowers with `return_tuple=True`; non-tuple
    /// results come back as a single-element vector).
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let mut result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let elems = result.decompose_tuple()?;
        if elems.is_empty() {
            Ok(vec![result])
        } else {
            Ok(elems)
        }
    }
}

/// A set of artifacts sharing one PJRT client.
pub struct ArtifactSet {
    client: xla::PjRtClient,
    dir: PathBuf,
}

impl ArtifactSet {
    /// Create a CPU PJRT client rooted at the default artifact directory.
    pub fn new() -> Result<Self> {
        Self::with_dir(artifacts_dir())
    }

    /// Create a CPU PJRT client rooted at `dir`.
    pub fn with_dir(dir: PathBuf) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, dir })
    }

    /// Platform name of the underlying PJRT client (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Directory artifacts are loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// True if `<dir>/<name>.hlo.txt` exists.
    pub fn available(&self, name: &str) -> bool {
        self.dir.join(format!("{name}.hlo.txt")).exists()
    }

    /// Load and compile artifact `name`.
    pub fn load(&self, name: &str) -> Result<Artifact> {
        Artifact::load(&self.client, &self.dir, name)
    }
}
