//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! The Python side (`python/compile/aot.py`) lowers the JAX/Pallas model
//! to **HLO text** under `artifacts/`. This module wraps the `xla` crate
//! (PJRT C API): an [`ArtifactSet`] owns one CPU client, an
//! [`Artifact`] owns one compiled executable, loaded once and reused for
//! the whole sweep. Python never runs at request time.
//!
//! Interchange is HLO *text*, not a serialized `HloModuleProto`: jax ≥ 0.5
//! emits protos with 64-bit instruction ids which xla_extension 0.5.1
//! rejects; the text parser reassigns ids and round-trips cleanly.

mod artifact;
mod engine;

pub use artifact::{artifacts_dir, Artifact, ArtifactSet};
pub use engine::{LatencyEngine, CONTRACT_VERSION, PARAM_SLOTS};
