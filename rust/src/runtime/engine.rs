//! The XLA-accelerated latency hot path.
//!
//! [`LatencyEngine`] executes the AOT-compiled JAX/Pallas kernel
//! (`artifacts/latency_batch_<N>.hlo.txt`) that evaluates the per-access
//! emulated-memory round-trip latency over a batch of addresses.
//!
//! ## Cross-layer contract (v1)
//!
//! The parameter encoding is shared with
//! `python/compile/kernels/latency.py` — any change must be made in both
//! places and bumped in [`CONTRACT_VERSION`]. The artifact takes three
//! inputs and returns a 2-tuple:
//!
//! ```text
//! inputs:  addresses i32[N], iparams i32[16], fparams f32[16]
//! outputs: (latency f32[N], mean f32[1])   -- cycles per access
//! ```
//!
//! `iparams` layout (integer-valued):
//!
//! | idx | field | meaning |
//! |-----|-------|---------|
//! | 0 | `topo` | 0 = folded Clos, 1 = 2D mesh |
//! | 1 | `log2_words_per_tile` | address-to-tile block distribution shift |
//! | 2 | `k` | number of memory tiles in the emulation |
//! | 3 | `log2_g0` | Clos: tiles per edge switch (log2) |
//! | 4 | `log2_g1` | Clos: tiles per chip (log2) |
//! | 5 | `log2_block` | mesh: tiles per block (log2) |
//! | 6 | `blocks_x` | mesh: system blocks per row |
//! | 7 | `chip_blocks_x` | mesh: blocks per row on one chip |
//! | 8 | `route_open` | 1 = routes pre-opened (t_open elided) |
//! | 9 | `client_tile` | tile index of the client processor |
//! | 10 | `tiles` | total system tiles (memory tile `r` maps to index `(client+1+r) mod tiles`) |
//! | 11..15 | reserved | must be 0 |
//!
//! `fparams` layout (cycles unless noted):
//!
//! | idx | field |
//! |-----|-------|
//! | 0 | `t_tile` (tile-to-switch link) |
//! | 1 | `t_switch` |
//! | 2 | `t_open` |
//! | 3 | `c_cont` (contention factor, dimensionless) |
//! | 4 | `t_serial_intra` |
//! | 5 | `t_serial_inter` |
//! | 6 | `t_mem` (tile SRAM access) |
//! | 7 | `link_edge_core` (Clos on-chip stage-1<->2 link) |
//! | 8 | `link_core_sys` (Clos inter-chip stage-2<->3 link) |
//! | 9 | `mesh_link` (per hop) |
//! | 10 | `mesh_cross_extra` (per chip crossing) |
//! | 11..15 | reserved, 0 |

use anyhow::{bail, Context, Result};

use super::artifact::{Artifact, ArtifactSet};
use crate::netmodel::KernelParams;

/// Version of the artifact parameter contract described in the module docs.
pub const CONTRACT_VERSION: u32 = 1;

/// Number of slots in each parameter vector.
pub const PARAM_SLOTS: usize = 16;

/// Executes the AOT latency kernel for one fixed batch size.
pub struct LatencyEngine {
    artifact: Artifact,
    batch: usize,
}

impl LatencyEngine {
    /// Load `latency_batch_<batch>` from `set`.
    pub fn load(set: &ArtifactSet, batch: usize) -> Result<Self> {
        let name = format!("latency_batch_{batch}");
        let artifact = set
            .load(&name)
            .with_context(|| format!("loading latency engine artifact `{name}`"))?;
        Ok(Self { artifact, batch })
    }

    /// The fixed batch size the artifact was lowered for.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Evaluate per-access latency for exactly `batch_size` addresses.
    ///
    /// Returns (per-access latency in cycles, mean over the whole batch).
    pub fn run(&self, addresses: &[i32], params: &KernelParams) -> Result<(Vec<f32>, f32)> {
        if addresses.len() != self.batch {
            bail!(
                "latency engine lowered for batch {}, got {} addresses",
                self.batch,
                addresses.len()
            );
        }
        let addr = xla::Literal::vec1(addresses);
        let ip = xla::Literal::vec1(&params.iparams[..]);
        let fp = xla::Literal::vec1(&params.fparams[..]);
        let outs = self.artifact.execute(&[addr, ip, fp])?;
        if outs.len() != 2 {
            bail!("latency artifact returned {} outputs, expected 2", outs.len());
        }
        let lat = outs[0].to_vec::<f32>()?;
        let mean = outs[1].to_vec::<f32>()?;
        Ok((lat, mean[0]))
    }

    /// Evaluate exactly `batch_size` addresses and return only the
    /// batch mean — skips materialising the 4·batch-byte latency vector
    /// on the host (the Monte-Carlo sweep hot path; see EXPERIMENTS.md
    /// §Perf).
    pub fn run_mean(&self, addresses: &[i32], params: &KernelParams) -> Result<f32> {
        if addresses.len() != self.batch {
            bail!(
                "latency engine lowered for batch {}, got {} addresses",
                self.batch,
                addresses.len()
            );
        }
        let addr = xla::Literal::vec1(addresses);
        let ip = xla::Literal::vec1(&params.iparams[..]);
        let fp = xla::Literal::vec1(&params.fparams[..]);
        let outs = self.artifact.execute(&[addr, ip, fp])?;
        if outs.len() != 2 {
            bail!("latency artifact returned {} outputs, expected 2", outs.len());
        }
        Ok(outs[1].to_vec::<f32>()?[0])
    }

    /// Evaluate a slice of any length by padding the final partial batch;
    /// the mean is recomputed over the true `addresses.len()` prefix.
    pub fn run_any(&self, addresses: &[i32], params: &KernelParams) -> Result<(Vec<f32>, f64)> {
        let mut out = Vec::with_capacity(addresses.len());
        let mut buf = vec![0i32; self.batch];
        for chunk in addresses.chunks(self.batch) {
            if chunk.len() == self.batch {
                let (lat, _) = self.run(chunk, params)?;
                out.extend_from_slice(&lat);
            } else {
                buf[..chunk.len()].copy_from_slice(chunk);
                // Pad with the first address; padding lanes are discarded.
                for slot in buf[chunk.len()..].iter_mut() {
                    *slot = chunk.first().copied().unwrap_or(0);
                }
                let (lat, _) = self.run(&buf, params)?;
                out.extend_from_slice(&lat[..chunk.len()]);
            }
        }
        let mean = out.iter().map(|&x| x as f64).sum::<f64>() / out.len().max(1) as f64;
        Ok((out, mean))
    }
}
