//! Failure injection / fuzzing: malformed inputs must produce errors,
//! never panics, across the public front ends (config parser, miniC
//! compiler, instruction decoder, interpreter).

use memclos::cc::{compile, Backend};
use memclos::config::Doc;
use memclos::emulation::controller::{expand_load, expand_store};
use memclos::emulation::{EmulationSetup, SequentialMachine, TopologyKind};
use memclos::isa::decode::{predecode, FastMachine};
use memclos::isa::interp::{DirectMemory, EmulatedChannelMemory, Machine, RunStats};
use memclos::isa::{decode, Inst};
use memclos::util::prop::{forall, Config};
use memclos::util::rng::Rng;

fn random_text(r: &mut Rng, alphabet: &[u8], max_len: usize) -> String {
    let len = r.below(max_len as u64 + 1) as usize;
    (0..len).map(|_| *r.choose(alphabet) as char).collect()
}

#[test]
fn config_parser_never_panics() {
    let alphabet: Vec<u8> =
        b"abz_09.=[]#\" \n\t-+xtrue".iter().copied().collect();
    forall(
        Config { cases: 2000, base_seed: 0xF0 },
        |r| random_text(r, &alphabet, 120),
        |text| {
            let _ = Doc::parse(text); // Ok or Err, never panic
            Ok(())
        },
    );
}

#[test]
fn minic_frontend_never_panics() {
    let alphabet: Vec<u8> =
        b"fnvarwhileifreturnglobal(){}[];=+-*/%<>&|^ \n09azmain,".iter().copied().collect();
    forall(
        Config { cases: 1500, base_seed: 0xF1 },
        |r| random_text(r, &alphabet, 200),
        |src| {
            let _ = compile(src, Backend::Direct);
            let _ = compile(src, Backend::Emulated);
            Ok(())
        },
    );
}

#[test]
fn decoder_never_panics_on_random_words() {
    forall(
        Config { cases: 5000, base_seed: 0xF2 },
        |r| [r.next_u64() as u32, r.next_u64() as u32],
        |words| {
            let _ = decode(words);
            Ok(())
        },
    );
}

#[test]
fn interpreter_contains_random_programs() {
    // Random instruction streams either halt, error out, or hit the
    // step limit — never panic, never escape the sandboxed memories.
    forall(
        Config { cases: 300, base_seed: 0xF3 },
        |r| {
            let n = 4 + r.below(60) as usize;
            let mut prog: Vec<Inst> = (0..n).map(|_| random_inst(r)).collect();
            prog.push(Inst::Halt);
            prog
        },
        |prog| {
            let mut mem = DirectMemory::new(SequentialMachine::paper_figures(false), 1 << 16);
            let mut m = Machine::new(&mut mem, 256);
            m.max_steps = 20_000;
            let _ = m.run(prog);
            Ok(())
        },
    );
}

fn random_inst(r: &mut Rng) -> Inst {
    let reg = |r: &mut Rng| r.below(16) as u8;
    match r.below(16) {
        0 => Inst::Add { d: reg(r), a: reg(r), b: reg(r) },
        1 => Inst::Sub { d: reg(r), a: reg(r), b: reg(r) },
        2 => Inst::Mul { d: reg(r), a: reg(r), b: reg(r) },
        3 => Inst::AddI { d: r.below(8) as u8, a: reg(r), imm: r.range_i64(-1000, 1000) as i32 },
        4 => Inst::LoadImm { d: r.below(8) as u8, imm: r.range_i64(-70000, 70000) as i32 },
        5 => Inst::Jump { offset: r.range_i64(-20, 20) as i32 },
        6 => Inst::BranchZ { c: r.below(8) as u8, offset: r.range_i64(-20, 20) as i32 },
        7 => Inst::BranchNZ { c: r.below(8) as u8, offset: r.range_i64(-20, 20) as i32 },
        8 => Inst::LoadLocal { d: r.below(8) as u8, a: reg(r), off: r.range_i64(-10, 300) as i32 },
        9 => Inst::StoreLocal { s: r.below(8) as u8, a: reg(r), off: r.range_i64(-10, 300) as i32 },
        10 => Inst::LoadGlobal { d: reg(r), a: reg(r) },
        11 => Inst::StoreGlobal { s: reg(r), a: reg(r) },
        12 => Inst::Send { chan: 0, src: reg(r) },
        13 => Inst::Recv { chan: 0, dest: reg(r) },
        14 => Inst::Call { target: r.below(60) as u32 },
        _ => Inst::Ret,
    }
}

const FUZZ_STEPS: u64 = 10_000;

/// Run a program on both interpreters (same step limit, fresh direct
/// memories); compare outcomes: identical stats on success, identical
/// error STRINGS on failure.
fn compare_both(prog: &[Inst]) -> Result<(), String> {
    let mut lmem = DirectMemory::new(SequentialMachine::paper_figures(false), 1 << 12);
    let mut legacy = Machine::new(&mut lmem, 64);
    legacy.max_steps = FUZZ_STEPS;
    let lres = legacy.run(prog);

    let Ok(decoded) = predecode(prog) else {
        // Predecoding is strictly *pre*-validation: it may reject
        // programs the legacy loop would only fault on (or never reach
        // the fault in) at run time. Reaching this point at all proves
        // neither path panicked — which is the property here.
        return Ok(());
    };
    let mut fmem = DirectMemory::new(SequentialMachine::paper_figures(false), 1 << 12);
    let mut fast = FastMachine::new(&mut fmem, 64);
    fast.max_steps = FUZZ_STEPS;
    let fres = fast.run(&decoded);

    match (lres, fres) {
        (Ok(ls), Ok(fs)) => {
            if ls != fs {
                return Err(format!("stats diverge: {ls:?} vs {fs:?}"));
            }
            for i in 0..16u8 {
                if legacy.reg(i) != fast.reg(i) {
                    return Err(format!("r{i} diverges"));
                }
            }
            Ok(())
        }
        (Err(le), Err(fe)) => {
            let (le, fe) = (le.to_string(), fe.to_string());
            if le != fe {
                return Err(format!("error strings diverge: `{le}` vs `{fe}`"));
            }
            Ok(())
        }
        (l, f) => Err(format!("outcome diverges: legacy {l:?} vs fast {f:?}")),
    }
}

fn adversarial_inst(r: &mut Rng, n: usize) -> Inst {
    let reg = |r: &mut Rng| r.below(8) as u8;
    let span = n as i64 + 8;
    match r.below(12) {
        // Out-of-range branch targets: far past the end (both loops
        // must report the same "fell off" error via the sentinel) and
        // in-range backwards (loops, bounded by the step limit).
        0 | 1 => Inst::Jump { offset: r.range_i64(-4, span) as i32 },
        2 => Inst::BranchZ { c: reg(r), offset: r.range_i64(-4, span) as i32 },
        3 => Inst::BranchNZ { c: reg(r), offset: r.range_i64(-4, span) as i32 },
        // Calls past the end resolve to the sentinel too.
        4 => Inst::Call { target: r.below(span as u64) as u32 },
        5 => Inst::Ret, // empty-stack trap
        // Local accesses far outside the 64-word local memory.
        6 => Inst::LoadLocal { d: reg(r), a: reg(r), off: r.range_i64(-40, 400) as i32 },
        7 => Inst::StoreLocal { s: reg(r), a: reg(r), off: r.range_i64(-40, 400) as i32 },
        8 => Inst::LoadImm { d: reg(r), imm: r.range_i64(-100, 5000) as i32 },
        9 => Inst::AddI { d: reg(r), a: reg(r), imm: r.range_i64(-100, 100) as i32 },
        10 => Inst::LoadGlobal { d: reg(r), a: reg(r) },
        _ => Inst::Halt,
    }
}

#[test]
fn predecode_adversarial_branches_match_legacy_error_strings() {
    // Random programs built from branch/call/trap-heavy instructions,
    // many with out-of-range targets and most WITHOUT a trailing Halt:
    // whenever both loops accept the program, outcome and error strings
    // must be identical (FastMachine's FellOff sentinel and trap exits
    // reproduce the legacy messages verbatim).
    forall(
        Config { cases: 600, base_seed: 0xF5 },
        |r| {
            let n = 3 + r.below(40) as usize;
            let mut prog: Vec<Inst> = (0..n).map(|_| adversarial_inst(r, n)).collect();
            if r.below(10) < 7 {
                prog.pop();
            } // usually no guaranteed Halt
            prog
        },
        |prog| compare_both(prog),
    );
}

#[test]
fn branch_past_end_error_strings_identical() {
    // The canonical out-of-range cases, pinned deterministically.
    for prog in [
        vec![Inst::Jump { offset: 100 }],
        vec![Inst::BranchZ { c: 0, offset: 7 }, Inst::Halt],
        vec![Inst::Call { target: 9999 }, Inst::Halt],
        vec![Inst::Nop, Inst::Nop], // falls off the end
        vec![Inst::Ret],
        vec![Inst::LoadLocal { d: 0, a: 0, off: 1000 }, Inst::Halt],
    ] {
        compare_both(&prog).unwrap();
    }
}

#[test]
fn predecode_truncated_channel_sequences_rejected_and_legacy_contained() {
    // Mutations of the canonical §2.1 expansions: truncations, dropped
    // instructions, corrupted tags, stray channel words. predecode must
    // reject malformed sequences up front with a channel-naming error;
    // the legacy loop (which discovers violations only at run time)
    // must be contained — error or not, never a panic — and whenever a
    // mutant predecodes cleanly, both machines must agree exactly.
    let setup = EmulationSetup::default_tech(TopologyKind::Clos, 256, 64, 255).unwrap();
    let mut base = vec![Inst::LoadImm { d: 1, imm: 100 }, Inst::LoadImm { d: 2, imm: 42 }];
    base.extend(expand_store(2, 1));
    base.extend(expand_load(3, 1));
    base.push(Inst::Halt);
    // Sanity: the unmutated program predecodes and both machines agree.
    assert!(predecode(&base).is_ok());

    let mut mutants: Vec<Vec<Inst>> = Vec::new();
    // Every truncation (drop the tail, re-terminate with Halt).
    for len in 1..base.len() {
        let mut m = base[..len].to_vec();
        m.push(Inst::Halt);
        mutants.push(m);
    }
    // Every single-instruction deletion.
    for i in 0..base.len() - 1 {
        let mut m = base.clone();
        m.remove(i);
        mutants.push(m);
    }
    // Corrupt each SendImm tag.
    for i in 0..base.len() {
        if let Inst::SendImm { chan, .. } = base[i] {
            let mut m = base.clone();
            m[i] = Inst::SendImm { chan, value: 7 };
            mutants.push(m);
        }
    }
    // Stray channel words at every position.
    for i in 0..base.len() {
        for stray in [
            Inst::Recv { chan: 0, dest: 4 },
            Inst::RecvAck { chan: 0 },
            Inst::Send { chan: 0, src: 4 },
        ] {
            let mut m = base.clone();
            m.insert(i, stray);
            mutants.push(m);
        }
    }

    let mut rejected = 0usize;
    for (mi, m) in mutants.iter().enumerate() {
        let decoded = predecode(m);
        // Legacy on the emulated-channel memory: must be contained.
        let mut lmem = EmulatedChannelMemory::new(setup.clone());
        let mut legacy = Machine::new(&mut lmem, 64);
        legacy.max_steps = FUZZ_STEPS;
        let lres: Result<RunStats, _> = legacy.run(m);
        match decoded {
            Err(e) => {
                rejected += 1;
                let msg = e.to_string();
                assert!(
                    msg.contains("pc "),
                    "mutant {mi}: predecode error does not locate the fault: `{msg}`"
                );
            }
            Ok(d) => {
                // Both accept: run fast on a fresh memory and compare.
                let mut fmem = EmulatedChannelMemory::new(setup.clone());
                let mut fast = FastMachine::new(&mut fmem, 64);
                fast.max_steps = FUZZ_STEPS;
                let fres = fast.run(&d);
                match (lres, fres) {
                    (Ok(ls), Ok(fs)) => assert_eq!(ls, fs, "mutant {mi}: stats diverge"),
                    (Err(le), Err(fe)) => assert_eq!(
                        le.to_string(),
                        fe.to_string(),
                        "mutant {mi}: error strings diverge"
                    ),
                    (l, f) => panic!("mutant {mi}: outcome diverges: {l:?} vs {f:?}"),
                }
            }
        }
    }
    assert!(
        rejected >= mutants.len() / 2,
        "expected most mutants rejected up front ({rejected}/{})",
        mutants.len()
    );
}

// ---------------------------------------------------------------------
// Snapshot adversarial mutation: every corruption of the binary format
// — truncation at any length, bit flips anywhere, semantically invalid
// fields behind a valid checksum, tier/backend/program mismatches —
// must come back as a typed, field-named [`SnapshotError`]. Nothing in
// this section may panic.
// ---------------------------------------------------------------------

use memclos::cc::corpus;
use memclos::isa::interp::{ExecCursor, RunOutcome};
use memclos::isa::snapshot::{
    fnv1a64, program_fingerprint, rebuild_memory, BackendSnap, Snapshot, SnapshotError, Tier,
};

/// A genuine mid-run snapshot: sieve on the fast machine over the
/// emulated backend, paused at a 300-cycle budget.
fn paused_sieve_snapshot() -> (Vec<Inst>, Snapshot) {
    let prog = corpus::all().into_iter().find(|p| p.name == "sieve").unwrap();
    let compiled = compile(prog.source, Backend::Emulated).unwrap();
    let decoded = predecode(&compiled.code).unwrap();
    let setup = EmulationSetup::default_tech(TopologyKind::Clos, 64, 64, 15).unwrap();
    let mut mem = EmulatedChannelMemory::new(setup);
    let mut cursor = ExecCursor::default();
    let (state, max_steps) = {
        let mut m = FastMachine::new(&mut mem, 1 << 16);
        let out = m.run_until(&decoded, &mut cursor, Some(300)).unwrap();
        assert!(matches!(out, RunOutcome::Paused), "sieve must outlive a 300-cycle budget");
        (m.export_state(&cursor), m.max_steps)
    };
    let snap = Snapshot {
        tier: Tier::Fast,
        backend: BackendSnap::of_emulated(&mem),
        space_words: mem.setup().map.space_words(),
        max_steps,
        program: "sieve".into(),
        program_fnv: program_fingerprint(&compiled.code),
        state,
        pages: Snapshot::pages_of(mem.store()),
    };
    (compiled.code, snap)
}

fn with_checksum(mut body: Vec<u8>) -> Vec<u8> {
    let sum = fnv1a64(&body);
    body.extend_from_slice(&sum.to_le_bytes());
    body
}

#[test]
fn snapshot_truncation_at_any_length_is_a_typed_error() {
    let (_, snap) = paused_sieve_snapshot();
    let bytes = snap.to_bytes();
    // Sanity: the untampered blob round-trips byte-identically.
    assert_eq!(Snapshot::from_bytes(&bytes).unwrap().to_bytes(), bytes);
    // Every prefix length near structural boundaries, plus a stride
    // sample through the bulk (page data dominates the byte count).
    let mut lens: Vec<usize> = (0..bytes.len().min(160)).collect();
    lens.extend((160..bytes.len()).step_by(211));
    lens.extend(bytes.len().saturating_sub(40)..bytes.len());
    for len in lens {
        let err = Snapshot::from_bytes(&bytes[..len])
            .expect_err(&format!("truncation to {len} bytes parsed"));
        // Short prefixes die in the header; anything longer fails the
        // trailing checksum (the tail it covers has been cut off).
        assert!(
            matches!(
                err,
                SnapshotError::Truncated { .. } | SnapshotError::Checksum | SnapshotError::BadMagic
            ),
            "truncation to {len}: unexpected error {err}"
        );
    }
}

#[test]
fn snapshot_single_byte_flips_are_always_rejected() {
    let (_, snap) = paused_sieve_snapshot();
    let bytes = snap.to_bytes();
    let mut positions: Vec<usize> = (0..bytes.len().min(64)).collect();
    positions.extend((64..bytes.len()).step_by(97));
    positions.extend(bytes.len().saturating_sub(16)..bytes.len());
    for i in positions {
        let mut m = bytes.clone();
        m[i] ^= 0x40;
        let err =
            Snapshot::from_bytes(&m).expect_err(&format!("flip at byte {i} parsed cleanly"));
        match err {
            SnapshotError::BadMagic => assert!(i < 4, "BadMagic from flip at {i}"),
            SnapshotError::Version { .. } => {
                assert!((4..8).contains(&i), "Version error from flip at {i}")
            }
            // Any flip in the body or in the trailer itself breaks the
            // checksum before field parsing even starts.
            SnapshotError::Checksum => assert!(i >= 8, "Checksum from header flip at {i}"),
            other => panic!("flip at {i}: unexpected error {other}"),
        }
    }
}

#[test]
fn snapshot_semantic_corruption_behind_a_valid_checksum_is_field_named() {
    let (code, snap) = paused_sieve_snapshot();
    let bytes = snap.to_bytes();
    let body = bytes[..bytes.len() - 8].to_vec();

    // Version skew: the version gate names both versions.
    let mut skew = body.clone();
    skew[4] = 99;
    match Snapshot::from_bytes(&with_checksum(skew)) {
        Err(SnapshotError::Version { found: 99, supported }) => assert_eq!(supported, 1),
        other => panic!("version skew: {other:?}"),
    }

    // Unknown tier byte (offset 8) and backend byte (offset 9).
    for (off, field) in [(8usize, "tier"), (9usize, "backend")] {
        let mut bad = body.clone();
        bad[off] = 9;
        match Snapshot::from_bytes(&with_checksum(bad)) {
            Err(SnapshotError::Field { field: f, .. }) => {
                assert_eq!(f, field, "corruption at offset {off}")
            }
            other => panic!("corruption at offset {off}: {other:?}"),
        }
    }

    // A recorded rank LUT that no default-tech replica can rebuild:
    // parses fine, but rebuild_memory refuses with the field name.
    let mut lut = snap.clone();
    if let BackendSnap::Emulated { rank_cycles, .. } = &mut lut.backend {
        rank_cycles[0] ^= 1;
    }
    let reparsed = Snapshot::from_bytes(&lut.to_bytes()).unwrap();
    match rebuild_memory(&reparsed) {
        Err(SnapshotError::Field { field: "rank_cycles", .. }) => {}
        other => panic!("tampered LUT: {other:?}"),
    }

    // Wrong machine: a fast-tier snapshot refuses a legacy resume, and
    // a fingerprint mismatch names the program it was taken of.
    match snap.check_tier(Tier::Legacy) {
        Err(SnapshotError::WrongTier { found: "fast", want: "legacy" }) => {}
        other => panic!("wrong tier: {other:?}"),
    }
    let other_prog = corpus::all().into_iter().find(|p| p.name == "fib_memo").unwrap();
    let other_code = compile(other_prog.source, Backend::Emulated).unwrap().code;
    match snap.check_program(&other_code) {
        Err(SnapshotError::Field { field: "program fingerprint", detail }) => {
            assert!(detail.contains("sieve"), "detail must name the program: {detail}")
        }
        other => panic!("wrong program: {other:?}"),
    }
    // The matching program still checks out.
    snap.check_program(&code).unwrap();
}

#[test]
fn emulation_setup_rejects_bad_points_gracefully() {
    // k out of range, non-square meshes, non-power-of-two capacities.
    assert!(EmulationSetup::default_tech(TopologyKind::Clos, 1024, 128, 0).is_err());
    assert!(EmulationSetup::default_tech(TopologyKind::Clos, 1024, 128, 1024).is_err());
    assert!(EmulationSetup::default_tech(TopologyKind::Mesh, 128, 128, 64).is_err());
    assert!(EmulationSetup::default_tech(TopologyKind::Clos, 1000, 128, 64).is_err());
    assert!(EmulationSetup::default_tech(TopologyKind::Clos, 1024, 96, 64).is_err());
}
