//! Failure injection / fuzzing: malformed inputs must produce errors,
//! never panics, across the public front ends (config parser, miniC
//! compiler, instruction decoder, interpreter).

use memclos::cc::{compile, Backend};
use memclos::config::Doc;
use memclos::emulation::{EmulationSetup, SequentialMachine, TopologyKind};
use memclos::isa::interp::{DirectMemory, Machine};
use memclos::isa::{decode, Inst};
use memclos::util::prop::{forall, Config};
use memclos::util::rng::Rng;

fn random_text(r: &mut Rng, alphabet: &[u8], max_len: usize) -> String {
    let len = r.below(max_len as u64 + 1) as usize;
    (0..len).map(|_| *r.choose(alphabet) as char).collect()
}

#[test]
fn config_parser_never_panics() {
    let alphabet: Vec<u8> =
        b"abz_09.=[]#\" \n\t-+xtrue".iter().copied().collect();
    forall(
        Config { cases: 2000, base_seed: 0xF0 },
        |r| random_text(r, &alphabet, 120),
        |text| {
            let _ = Doc::parse(text); // Ok or Err, never panic
            Ok(())
        },
    );
}

#[test]
fn minic_frontend_never_panics() {
    let alphabet: Vec<u8> =
        b"fnvarwhileifreturnglobal(){}[];=+-*/%<>&|^ \n09azmain,".iter().copied().collect();
    forall(
        Config { cases: 1500, base_seed: 0xF1 },
        |r| random_text(r, &alphabet, 200),
        |src| {
            let _ = compile(src, Backend::Direct);
            let _ = compile(src, Backend::Emulated);
            Ok(())
        },
    );
}

#[test]
fn decoder_never_panics_on_random_words() {
    forall(
        Config { cases: 5000, base_seed: 0xF2 },
        |r| [r.next_u64() as u32, r.next_u64() as u32],
        |words| {
            let _ = decode(words);
            Ok(())
        },
    );
}

#[test]
fn interpreter_contains_random_programs() {
    // Random instruction streams either halt, error out, or hit the
    // step limit — never panic, never escape the sandboxed memories.
    forall(
        Config { cases: 300, base_seed: 0xF3 },
        |r| {
            let n = 4 + r.below(60) as usize;
            let mut prog: Vec<Inst> = (0..n).map(|_| random_inst(r)).collect();
            prog.push(Inst::Halt);
            prog
        },
        |prog| {
            let mut mem = DirectMemory::new(SequentialMachine::paper_figures(false), 1 << 16);
            let mut m = Machine::new(&mut mem, 256);
            m.max_steps = 20_000;
            let _ = m.run(prog);
            Ok(())
        },
    );
}

fn random_inst(r: &mut Rng) -> Inst {
    let reg = |r: &mut Rng| r.below(16) as u8;
    match r.below(16) {
        0 => Inst::Add { d: reg(r), a: reg(r), b: reg(r) },
        1 => Inst::Sub { d: reg(r), a: reg(r), b: reg(r) },
        2 => Inst::Mul { d: reg(r), a: reg(r), b: reg(r) },
        3 => Inst::AddI { d: r.below(8) as u8, a: reg(r), imm: r.range_i64(-1000, 1000) as i32 },
        4 => Inst::LoadImm { d: r.below(8) as u8, imm: r.range_i64(-70000, 70000) as i32 },
        5 => Inst::Jump { offset: r.range_i64(-20, 20) as i32 },
        6 => Inst::BranchZ { c: r.below(8) as u8, offset: r.range_i64(-20, 20) as i32 },
        7 => Inst::BranchNZ { c: r.below(8) as u8, offset: r.range_i64(-20, 20) as i32 },
        8 => Inst::LoadLocal { d: r.below(8) as u8, a: reg(r), off: r.range_i64(-10, 300) as i32 },
        9 => Inst::StoreLocal { s: r.below(8) as u8, a: reg(r), off: r.range_i64(-10, 300) as i32 },
        10 => Inst::LoadGlobal { d: reg(r), a: reg(r) },
        11 => Inst::StoreGlobal { s: reg(r), a: reg(r) },
        12 => Inst::Send { chan: 0, src: reg(r) },
        13 => Inst::Recv { chan: 0, dest: reg(r) },
        14 => Inst::Call { target: r.below(60) as u32 },
        _ => Inst::Ret,
    }
}

#[test]
fn emulation_setup_rejects_bad_points_gracefully() {
    // k out of range, non-square meshes, non-power-of-two capacities.
    assert!(EmulationSetup::default_tech(TopologyKind::Clos, 1024, 128, 0).is_err());
    assert!(EmulationSetup::default_tech(TopologyKind::Clos, 1024, 128, 1024).is_err());
    assert!(EmulationSetup::default_tech(TopologyKind::Mesh, 128, 128, 64).is_err());
    assert!(EmulationSetup::default_tech(TopologyKind::Clos, 1000, 128, 64).is_err());
    assert!(EmulationSetup::default_tech(TopologyKind::Clos, 1024, 96, 64).is_err());
}
