//! The serve determinism invariant, pinned: a response payload is a
//! pure function of its request's canonical key (which folds in the
//! seed) — bit-identical regardless of batching, concurrency, cache
//! state or arrival order.
//!
//! One request corpus is replayed through three schedules:
//!
//! 1. **serial** — batching disabled, one request at a time, cold cache;
//! 2. **batched-concurrent** — batching enabled, all requests in
//!    flight at once from worker threads;
//! 3. **adversarial** — a tiny (2-entry) cache forcing evictions, the
//!    corpus shuffled, duplicated and replayed twice.
//!
//! Every schedule must produce the same payload bytes per canonical
//! key. The file also covers the wire-schema edges the in-module unit
//! tests do not: envelope/error shapes as a client library would see
//! them.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use memclos::api::Mode;
use memclos::serve::proto::Request;
use memclos::serve::service::{ServeConfig, Service};
use memclos::serve::ServeError;
use memclos::util::json::Json;

/// A mixed-kind corpus with deliberate duplicates (same canonical key
/// from different ids) and near-duplicates (same point, different
/// seed).
fn corpus() -> Vec<Request> {
    let texts = [
        "{\"id\": 1, \"kind\": \"latency\", \"tiles\": 256, \"k\": 63, \"mem_kb\": 64, \"seed\": 0}",
        "{\"id\": 2, \"kind\": \"latency\", \"tiles\": 256, \"k\": 63, \"mem_kb\": 64, \"seed\": 0}",
        "{\"id\": 3, \"kind\": \"latency\", \"tiles\": 256, \"k\": 63, \"mem_kb\": 64, \"seed\": 1}",
        "{\"id\": 4, \"kind\": \"latency\", \"tiles\": 256, \"k\": 255, \"mem_kb\": 64, \"seed\": 0}",
        "{\"id\": 5, \"kind\": \"latency\", \"tiles\": 1024, \"k\": 255, \"mem_kb\": 64, \"seed\": 0}",
        "{\"id\": 6, \"kind\": \"sweep\", \"tiles\": 64, \"mem_kb\": 64, \"seed\": 0}",
        "{\"id\": 7, \"kind\": \"contention\", \"tiles\": 64, \"k\": 15, \"mem_kb\": 64, \"clients\": 2, \"accesses\": 32, \"pattern\": \"zipf:1.2\", \"seed\": 0}",
        "{\"id\": 8, \"kind\": \"contention\", \"tiles\": 64, \"k\": 15, \"mem_kb\": 64, \"clients\": 2, \"accesses\": 32, \"pattern\": \"zipf:1.2\", \"seed\": 7}",
        "{\"id\": 9, \"kind\": \"emulation\", \"tiles\": 256, \"k\": 255, \"program\": \"sum_squares\", \"seed\": 0}",
    ];
    texts.iter().map(|t| Request::from_bytes(t.as_bytes()).unwrap()).collect()
}

fn service(batch_max: usize, cache_entries: usize) -> Arc<Service> {
    Arc::new(Service::new(ServeConfig {
        mode: Mode::Exact,
        batch_max,
        cache_entries,
        jobs: 2,
        linger: Duration::from_millis(2),
        ..ServeConfig::default()
    }))
}

/// Payloads per canonical key under one schedule.
fn payloads_serial(svc: &Service, reqs: &[Request]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    for r in reqs {
        let p = svc.handle(r).unwrap_or_else(|e| panic!("{}: {e}", r.canonical_key()));
        let prev = out.insert(r.canonical_key(), p.to_string());
        if let Some(prev) = prev {
            assert_eq!(prev, *out[&r.canonical_key()], "same key, same bytes, same schedule");
        }
    }
    out
}

fn payloads_concurrent(svc: &Arc<Service>, reqs: &[Request]) -> HashMap<String, String> {
    let handles: Vec<_> = reqs
        .iter()
        .map(|r| {
            let svc = svc.clone();
            let r = r.clone();
            std::thread::spawn(move || (r.canonical_key(), svc.handle(&r).unwrap().to_string()))
        })
        .collect();
    let mut out = HashMap::new();
    for h in handles {
        let (key, payload) = h.join().unwrap();
        if let Some(prev) = out.insert(key.clone(), payload) {
            assert_eq!(prev, out[&key], "concurrent duplicates must agree");
        }
    }
    out
}

#[test]
fn payloads_are_schedule_invariant() {
    let reqs = corpus();

    // Schedule 1: serial, unbatched, cold cache — the oracle.
    let want = payloads_serial(&service(1, 4096), &reqs);

    // Schedule 2: batched + concurrent.
    let got = payloads_concurrent(&service(8, 4096), &reqs);
    assert_eq!(want, got, "batching/concurrency changed payload bytes");

    // Schedule 3: adversarial — 2-entry cache (evictions guaranteed),
    // shuffled + duplicated corpus, replayed twice.
    let svc = service(4, 2);
    let mut order: Vec<Request> = reqs.iter().rev().cloned().collect();
    order.extend(reqs.iter().cloned());
    let first = payloads_serial(&svc, &order);
    assert_eq!(want, first, "evicting cache changed payload bytes");
    let second = payloads_serial(&svc, &order);
    assert_eq!(want, second, "replay after evictions changed payload bytes");
    assert!(svc.stats().cache.evictions > 0, "the tiny cache must actually evict");
}

#[test]
fn a_warm_cache_serves_the_identical_allocation() {
    let svc = service(1, 4096);
    let reqs = corpus();
    let cold: Vec<Arc<String>> = reqs.iter().map(|r| svc.handle(r).unwrap()).collect();
    let miss_floor = svc.stats().cache.misses;
    let warm: Vec<Arc<String>> = reqs.iter().map(|r| svc.handle(r).unwrap()).collect();
    for (c, w) in cold.iter().zip(&warm) {
        assert!(Arc::ptr_eq(c, w), "warm pass must return the cached allocation");
    }
    assert_eq!(svc.stats().cache.misses, miss_floor, "warm pass evaluates nothing");
    assert_eq!(svc.stats().cache.hits as usize, reqs.len() + 1, "one duplicate in the cold pass");
}

#[test]
fn envelope_and_error_shapes_survive_the_wire() {
    use memclos::serve::proto::Response;

    // Success envelope: id echo + raw payload splice.
    let svc = service(1, 16);
    let req = Request::from_bytes(
        b"{\"id\": 42, \"kind\": \"latency\", \"tiles\": 64, \"k\": 15, \"mem_kb\": 64}",
    )
    .unwrap();
    let payload = svc.handle(&req).unwrap();
    let wire = Response::ok_wire(req.id, &payload);
    let resp = Response::from_bytes(wire.as_bytes()).unwrap();
    assert!(resp.ok);
    assert_eq!(resp.id, 42);
    // The spliced payload parses back to the same document.
    assert_eq!(resp.result.unwrap(), Json::parse(&payload).unwrap());

    // Error envelopes: overload marker only for sheds.
    for (err, overload) in [
        (ServeError::Overload("queue full"), true),
        (ServeError::Draining, true),
        (ServeError::field("tiles", "need 1 <= tiles"), false),
        (ServeError::Eval("backend exploded".into()), false),
    ] {
        let resp = Response::from_bytes(Response::error_wire(9, &err).as_bytes()).unwrap();
        assert!(!resp.ok);
        assert_eq!(resp.overload, overload, "{err}");
        assert_eq!(resp.id, 9);
        assert!(resp.error.is_some());
    }
}

#[test]
fn malformed_requests_are_typed_not_panics() {
    for bytes in [
        &b"not json"[..],
        b"[]",
        b"{\"kind\": 7}",
        b"{\"kind\": \"latency\", \"tiles\": \"many\"}",
        b"{\"kind\": \"latency\", \"seed\": -1}",
        b"{\"kind\": \"latency\", \"seed\": 1.5}",
        b"{\"kind\": \"contention\", \"pattern\": \"zipf:\"}",
        b"{\"kind\": \"latency\", \"tiles\": 64, \"k\": 100}",
        b"{\"kind\": \"latency\", \"unknown_member\": 1}",
        b"\xff\xfe",
    ] {
        let err = Request::from_bytes(bytes).unwrap_err();
        // Every one of these is a client bug with a printable message,
        // never an overload.
        assert!(!err.is_overload(), "{err}");
        assert!(!format!("{err}").is_empty());
    }
}
