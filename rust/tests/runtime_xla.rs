//! Integration: the AOT artifacts produced by `make artifacts` load,
//! compile and execute on the PJRT CPU client from rust, and the numbers
//! agree with the kernel contract.
//!
//! Requires `artifacts/` to exist (run `make artifacts` first); the tests
//! are skipped with a message otherwise so `cargo test` stays green in a
//! fresh checkout.

use memclos::netmodel::KernelParams;
use memclos::runtime::{ArtifactSet, LatencyEngine};

fn params_same_edge() -> KernelParams {
    // 15 memory tiles on the client's edge switch, 4 KiB-word tiles.
    let mut ip = [0i32; 16];
    let mut fp = [0f32; 16];
    ip[0] = 0; // clos
    ip[1] = 12; // log2 words/tile
    ip[2] = 15; // k
    ip[3] = 4; // log2 g0
    ip[4] = 8; // log2 g1
    ip[5] = 4; // mesh block (unused)
    ip[6] = 8;
    ip[7] = 4;
    ip[10] = 1024; // system tiles
    fp[0] = 1.0; // t_tile
    fp[1] = 2.0; // t_switch
    fp[2] = 5.0; // t_open
    fp[3] = 1.0; // c_cont
    fp[4] = 0.0; // ser intra
    fp[5] = 2.0; // ser inter
    fp[6] = 1.0; // t_mem
    fp[7] = 2.0; // link edge-core
    fp[8] = 8.0; // link core-sys
    fp[9] = 1.0; // mesh link
    fp[10] = 1.0; // mesh cross extra
    KernelParams { iparams: ip, fparams: fp }
}

fn artifacts_ready() -> Option<ArtifactSet> {
    let set = ArtifactSet::new().expect("PJRT CPU client");
    if set.available("latency_batch_4096") {
        Some(set)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn latency_batch_same_edge_constant() {
    let Some(set) = artifacts_ready() else { return };
    let engine = LatencyEngine::load(&set, 4096).expect("load latency_batch_4096");
    let params = params_same_edge();
    // All addresses map to tiles 1..=15 on the client's switch: d=0,
    // one_way = 2*1 + 0 + 1*(5+2) = 9, round trip = 19 cycles.
    let addresses: Vec<i32> = (0..4096).map(|i| (i * 13) % (15 << 12)).collect();
    let (lat, mean) = engine.run(&addresses, &params).expect("execute");
    assert_eq!(lat.len(), 4096);
    assert!(lat.iter().all(|&l| l == 19.0), "expected constant 19.0");
    assert!((mean - 19.0).abs() < 1e-5, "mean={mean}");
}

#[test]
fn latency_batch_interchip_constant() {
    let Some(set) = artifacts_ready() else { return };
    let engine = LatencyEngine::load(&set, 4096).expect("load");
    let mut params = params_same_edge();
    params.iparams[2] = 1023; // k: spread over 4 chips
    // Addresses on tiles >= 256 (other chips): d=4,
    // one_way = 2 + 2 + 5*(5+2) + (2*2 + 2*8) = 59, rt = 119.
    let base: i64 = 256 << 12;
    let addresses: Vec<i32> =
        (0..4096).map(|i| (base + (i * 7919) % ((1023i64 - 256) << 12)) as i32).collect();
    let (lat, _) = engine.run(&addresses, &params).expect("execute");
    assert!(lat.iter().all(|&l| l == 119.0), "expected constant 119.0, got {}", lat[0]);
}

#[test]
fn run_any_pads_and_averages() {
    let Some(set) = artifacts_ready() else { return };
    let engine = LatencyEngine::load(&set, 4096).expect("load");
    let params = params_same_edge();
    let addresses: Vec<i32> = (0..5000).map(|i| (i * 31) % (15 << 12)).collect();
    let (lat, mean) = engine.run_any(&addresses, &params).expect("execute");
    assert_eq!(lat.len(), 5000);
    assert!((mean - 19.0).abs() < 1e-9);
}

#[test]
fn mix_sweep_artifact_executes() {
    let Some(set) = artifacts_ready() else { return };
    if !set.available("mix_sweep_256") {
        return;
    }
    let art = set.load("mix_sweep_256").expect("load mix_sweep_256");
    let m = 256usize;
    let g: Vec<f32> = (0..m).map(|i| 0.5 * i as f32 / m as f32).collect();
    let l = vec![0.2f32; m];
    let lat_emu = vec![119.0f32; m];
    let lat_seq = vec![35.0f32];
    let outs = art
        .execute(&[
            xla::Literal::vec1(&g),
            xla::Literal::vec1(&l),
            xla::Literal::vec1(&lat_emu),
            xla::Literal::vec1(&lat_seq),
        ])
        .expect("execute");
    assert_eq!(outs.len(), 3);
    let slowdown = outs[0].to_vec::<f32>().expect("slowdown");
    // g=0 -> parity; monotone nondecreasing in g
    assert!((slowdown[0] - 1.0).abs() < 1e-6);
    for w in slowdown.windows(2) {
        assert!(w[1] >= w[0] - 1e-6);
    }
    // paper §7.2 band: generous 1.5-2.5 worst-case at g=0.5... our point
    // check: at g=0.15 (dhrystone-ish) slowdown is within 2-3.
    let i = (0.15 / 0.5 * m as f64) as usize;
    assert!(slowdown[i] > 1.5 && slowdown[i] < 3.5, "slowdown={}", slowdown[i]);
}
