//! Integration across the whole modelling stack: floorplan -> latency
//! model -> emulation machine -> interpreter -> paper claims.

use memclos::cc::{compile, corpus, Backend};
use memclos::emulation::{EmulationSetup, SequentialMachine, TopologyKind};
use memclos::isa::interp::{DirectMemory, EmulatedChannelMemory, Machine};
use memclos::workload::{predict_slowdown, SyntheticProgram, DHRYSTONE_MIX};

/// §7.2 headline: executing a general-purpose program against the
/// emulated memory is a factor ~2-3 slower than the sequential machine,
/// measured end-to-end through the interpreter (not the closed form).
#[test]
fn headline_slowdown_measured_by_execution() {
    let seq = SequentialMachine::with_measured_dram(1);
    let k = 1023usize;
    let setup = EmulationSetup::default_tech(TopologyKind::Clos, 1024, 128, k).unwrap();
    let space = setup.map.space_words();
    let emu_lat = setup.expected_latency();

    let prog = SyntheticProgram::generate(DHRYSTONE_MIX, 30_000, space, 11);

    let mut dmem = DirectMemory::new(seq, space);
    let mut dm = Machine::new(&mut dmem, 64);
    let dstats = dm.run(&prog.direct).unwrap();

    let mut emem = EmulatedChannelMemory::new(setup);
    let mut em = Machine::new(&mut emem, 64);
    let estats = em.run(&prog.emulated).unwrap();

    let slowdown = estats.cycles as f64 / dstats.cycles as f64;
    assert!(
        slowdown > 1.5 && slowdown < 3.3,
        "measured slowdown {slowdown} outside the paper band"
    );

    // The closed-form prediction and the measured execution agree
    // (the executed mix differs slightly from the target because of
    // address-setup instructions; allow 15%).
    let (_, _, g) = dstats.mix();
    let mix = memclos::workload::InstructionMix::new(0.2 / (1.0 + 0.2), g);
    let predicted = predict_slowdown(&mix, emu_lat, seq.dram_ns);
    let rel = (slowdown - predicted).abs() / predicted;
    assert!(rel < 0.15, "measured {slowdown} vs predicted {predicted}");
}

/// Every corpus program computes identical results on both machines at
/// several design points, and the emulated run is never faster than
/// free (sanity: slowdown >= 0.5) nor absurd (<= 6x).
#[test]
fn corpus_runs_at_multiple_design_points() {
    let seq = SequentialMachine::with_measured_dram(1);
    for (kind, tiles, k) in [
        (TopologyKind::Clos, 256usize, 255usize),
        (TopologyKind::Clos, 4096, 4095),
        (TopologyKind::Mesh, 1024, 1023),
    ] {
        for prog in [corpus::SUM_SQUARES, corpus::SIEVE, corpus::HASHTAB] {
            let direct = compile(prog.source, Backend::Direct).unwrap();
            let emulated = compile(prog.source, Backend::Emulated).unwrap();

            let mut dmem = DirectMemory::new(seq, 1 << 22);
            let mut dm = Machine::new(&mut dmem, 1 << 16);
            let ds = dm.run(&direct.code).unwrap();
            let dres = dm.reg(0);

            let setup = EmulationSetup::default_tech(kind, tiles, 128, k).unwrap();
            let mut emem = EmulatedChannelMemory::new(setup);
            let mut em = Machine::new(&mut emem, 1 << 16);
            let es = em.run(&emulated.code).unwrap();
            let eres = em.reg(0);

            assert_eq!(dres, eres, "{} at {kind:?}/{tiles}", prog.name);
            let slowdown = es.cycles as f64 / ds.cycles as f64;
            assert!(
                (0.5..=6.0).contains(&slowdown),
                "{} at {kind:?}/{tiles}: slowdown {slowdown}",
                prog.name
            );
        }
    }
}

/// Small emulations (single switch) BEAT the sequential machine —
/// the §7.2 "speedup up to 16 tiles" observation, end to end.
#[test]
fn small_emulation_speedup_end_to_end() {
    let seq = SequentialMachine::with_measured_dram(1);
    let setup = EmulationSetup::default_tech(TopologyKind::Clos, 1024, 128, 15).unwrap();
    let space = setup.map.space_words();
    let prog = SyntheticProgram::generate(DHRYSTONE_MIX, 20_000, space, 5);

    let mut dmem = DirectMemory::new(seq, space);
    let mut dm = Machine::new(&mut dmem, 64);
    let dstats = dm.run(&prog.direct).unwrap();

    let mut emem = EmulatedChannelMemory::new(setup);
    let mut em = Machine::new(&mut emem, 64);
    let estats = em.run(&prog.emulated).unwrap();

    assert!(
        estats.cycles < dstats.cycles,
        "single-switch emulation should beat DRAM ({} vs {})",
        estats.cycles,
        dstats.cycles
    );
}
