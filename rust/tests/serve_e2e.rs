//! End-to-end serve tests: a real in-process [`Server`] on an
//! ephemeral port, driven over real TCP — by a raw frame client, by
//! the closed-loop load generator, and by an overload burst against
//! deliberately tiny admission bounds.

use std::io::Write as _;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use memclos::api::Mode;
use memclos::serve::loadgen::{self, LoadgenOpts};
use memclos::serve::proto::Response;
use memclos::serve::service::{ServeConfig, Service};
use memclos::serve::{read_frame, write_frame, Server, ServerConfig};
use memclos::util::json::Json;

fn start(server_cfg: ServerConfig) -> Server {
    let service = Arc::new(Service::new(ServeConfig {
        mode: Mode::Exact,
        jobs: 2,
        linger: Duration::from_millis(1),
        ..ServeConfig::default()
    }));
    Server::start(service, &server_cfg).expect("server starts")
}

fn request(stream: &mut TcpStream, body: &str) -> Response {
    write_frame(stream, body.as_bytes()).expect("send");
    let bytes = read_frame(stream).expect("read").expect("one response frame");
    Response::from_bytes(&bytes).expect("parseable envelope")
}

#[test]
fn raw_client_round_trips_and_drains_cleanly() {
    let server = start(ServerConfig { addr: "127.0.0.1:0".into(), ..ServerConfig::default() });
    let addr = server.local_addr();
    let mut conn = TcpStream::connect(addr).expect("connect");

    let pong = request(&mut conn, "{\"id\": 1, \"kind\": \"ping\"}");
    assert!(pong.ok && pong.id == 1);
    assert_eq!(pong.result.unwrap().get("pong").and_then(Json::as_bool), Some(true));

    let lat = request(
        &mut conn,
        "{\"id\": 2, \"kind\": \"latency\", \"tiles\": 256, \"k\": 63, \"mem_kb\": 64}",
    );
    assert!(lat.ok && lat.id == 2, "{lat:?}");
    let doc = lat.result.unwrap();
    assert_eq!(doc.get("bench").and_then(Json::as_str), Some("serve.latency"));

    // Malformed JSON gets a typed error and KEEPS the connection.
    write_frame(&mut conn, b"{not json").expect("send garbage");
    let bad = Response::from_bytes(&read_frame(&mut conn).unwrap().unwrap()).unwrap();
    assert!(!bad.ok && !bad.overload);
    assert!(bad.error.unwrap().contains("JSON"), "typed parse error");
    let again = request(&mut conn, "{\"id\": 3, \"kind\": \"ping\"}");
    assert!(again.ok && again.id == 3, "connection survives garbage JSON");

    // Drain: shutdown is acknowledged, then EOF at a frame boundary.
    let shut = request(&mut conn, "{\"id\": 4, \"kind\": \"shutdown\"}");
    assert!(shut.ok && shut.id == 4);
    assert!(matches!(read_frame(&mut conn), Ok(None)), "clean EOF after drain");
    assert!(server.is_draining());
    let report = server.join();
    assert!(report.served >= 4, "{report}");
    assert_eq!(report.frame_errors, 0, "{report}");
}

#[test]
fn loadgen_drives_and_drains_a_live_server() {
    let server = start(ServerConfig { addr: "127.0.0.1:0".into(), ..ServerConfig::default() });
    let opts = LoadgenOpts {
        addr: server.local_addr().to_string(),
        clients: 2,
        requests: 8,
        seed: 0x10AD,
        shutdown: true,
    };
    let summary = loadgen::run(&opts).expect("loadgen runs");
    assert_eq!(summary.sent, 16);
    assert_eq!(summary.sent, summary.ok + summary.overload + summary.errors);
    assert_eq!(summary.errors, 0, "{}", summary.render());
    assert!(summary.ok > 0);
    assert_eq!(summary.drain_clean, Some(true), "{}", summary.render());
    let stats = summary.server_stats.as_ref().expect("stats captured before drain");
    assert!(stats.get("served").and_then(Json::as_u64).unwrap() >= 16);

    // The report is a well-formed document of the BENCH schema family.
    let report = summary.report().render();
    let doc = Json::parse(&report).expect("report parses");
    assert_eq!(doc.get("bench").and_then(Json::as_str), Some("serve"));

    let report = server.join();
    assert!(report.served >= 16, "{report}");
}

#[test]
fn overload_sheds_with_typed_rejections_and_answers_every_frame() {
    // Tiny bounds: 1 worker, queue depth 1, 1 in-flight per session —
    // a pipelined burst must shed most of itself.
    let server = start(ServerConfig {
        addr: "127.0.0.1:0".into(),
        net_workers: 1,
        queue_depth: 1,
        session_inflight: 1,
    });
    let mut conn = TcpStream::connect(server.local_addr()).expect("connect");

    // Pipeline slow contention requests without reading responses.
    const BURST: usize = 10;
    for i in 0..BURST {
        let body = format!(
            "{{\"id\": {}, \"kind\": \"contention\", \"tiles\": 64, \"k\": 15, \"mem_kb\": 64, \"clients\": 4, \"accesses\": 2000, \"seed\": {i}}}",
            100 + i
        );
        write_frame(&mut conn, body.as_bytes()).expect("send");
    }
    let mut seen = std::collections::BTreeSet::new();
    let mut ok = 0usize;
    let mut shed = 0usize;
    for _ in 0..BURST {
        let bytes = read_frame(&mut conn).expect("read").expect("every frame is answered");
        let resp = Response::from_bytes(&bytes).expect("envelope");
        assert!(seen.insert(resp.id), "duplicate response id {}", resp.id);
        if resp.ok {
            ok += 1;
        } else {
            assert!(resp.overload, "only overloads may fail here: {resp:?}");
            assert!(resp.error.unwrap().contains("overload"));
            shed += 1;
        }
    }
    assert_eq!(ok + shed, BURST);
    assert!(ok >= 1, "at least the first admitted request is served");
    assert!(shed >= 1, "the burst must overrun depth-1 admission");
    for i in 0..BURST {
        assert!(seen.contains(&(100 + i as u64)), "response for id {} missing", 100 + i);
    }

    server.request_shutdown();
    drop(conn);
    let report = server.join();
    assert!(report.overloads >= shed as u64, "{report}");
}

#[test]
fn an_oversized_frame_is_rejected_and_the_connection_closed() {
    let server = start(ServerConfig { addr: "127.0.0.1:0".into(), ..ServerConfig::default() });
    let mut conn = TcpStream::connect(server.local_addr()).expect("connect");
    // A prefix past MAX_FRAME: the server answers with a typed framing
    // error and closes (no resync is possible mid-stream).
    let huge = ((memclos::serve::MAX_FRAME + 1) as u32).to_be_bytes();
    conn.write_all(&huge).expect("send prefix");
    conn.flush().unwrap();
    let bytes = read_frame(&mut conn).expect("read").expect("error response");
    let resp = Response::from_bytes(&bytes).expect("envelope");
    assert!(!resp.ok);
    assert!(resp.error.unwrap().contains("exceeds"), "typed oversize error");
    assert!(matches!(read_frame(&mut conn), Ok(None)), "connection closed after violation");

    server.request_shutdown();
    let report = server.join();
    assert_eq!(report.frame_errors, 1, "{report}");
}
