//! Rust-side goldens for the Python cross-checks in `python/tests/`.
//!
//! Two snapshots pin the exact bit streams the Python ports must
//! reproduce:
//!
//! * `tests/golden/pyparity_rng.json` — raw xoshiro256** draws, Lemire
//!   `below` draws, and `point_seed` values for a few seeds
//!   (`python/tests/test_rng_parity.py` replays them through
//!   `memclos_rng.py`).
//! * `tests/golden/pyparity_fuzzgen.json` — FNV-1a digests of the
//!   first 100 rendered fuzz cases for sweep seed 0
//!   (`python/tests/test_fuzzgen_parity.py` regenerates every program
//!   draw for draw and must match all 100).
//!
//! Same convention as `golden_figures`: a missing snapshot is seeded
//! from the current output (the first toolchain-bearing CI run writes
//! the initial set); `UPDATE_GOLDEN=1` regenerates in place. All u64s
//! are rendered as decimal *strings* so no JSON reader mangles values
//! above 2^53.

use std::fmt::Write as _;
use std::path::PathBuf;

use memclos::coordinator::point_seed;
use memclos::util::rng::Rng;
use memclos::workload::fuzzgen;

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden").join(name)
}

fn check_or_seed(name: &str, rendered: &str) {
    let path = golden_path(name);
    std::fs::create_dir_all(path.parent().unwrap()).expect("creating tests/golden");
    let update = std::env::var("UPDATE_GOLDEN").map(|v| v == "1").unwrap_or(false);
    if update || !path.exists() {
        std::fs::write(&path, rendered).expect("writing golden snapshot");
        eprintln!("seeded golden snapshot {}", path.display());
        return;
    }
    let want = std::fs::read_to_string(&path).expect("reading golden snapshot");
    if want != rendered {
        let new = path.with_extension("json.new");
        std::fs::write(&new, rendered).expect("writing fresh output");
        panic!(
            "{name} drifted from its golden snapshot — the Python port's reference \
             stream must not move silently.\n  golden: {}\n  fresh:  {}",
            path.display(),
            new.display()
        );
    }
}

fn str_list<T: std::fmt::Display>(values: impl IntoIterator<Item = T>) -> String {
    let items: Vec<String> = values.into_iter().map(|v| format!("\"{v}\"")).collect();
    format!("[{}]", items.join(", "))
}

#[test]
fn rng_golden_pins_the_stream_for_the_python_port() {
    let seeds: [u64; 4] = [0, 1, 0xDEAD_BEEF, u64::MAX];
    let mut out = String::from("{\"seeds\": [");
    for (i, &seed) in seeds.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        let mut r = Rng::new(seed);
        let raw: Vec<u64> = (0..8).map(|_| r.next_u64()).collect();
        let below10: Vec<u64> = (0..8).map(|_| r.below(10)).collect();
        let below_big: Vec<u64> = (0..4).map(|_| r.below(1_000_000_007)).collect();
        let _ = write!(
            out,
            "{{\"seed\": \"{seed}\", \"next_u64\": {}, \"below_10\": {}, \"below_1000000007\": {}}}",
            str_list(raw),
            str_list(below10),
            str_list(below_big)
        );
    }
    out.push_str("], \"point_seed\": [");
    let pairs: [(u64, u64); 4] = [(0, 0), (0, 1), (7, 42), (0xC105, u64::MAX)];
    for (i, &(seed, key)) in pairs.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        let _ = write!(
            out,
            "{{\"seed\": \"{seed}\", \"key\": \"{key}\", \"value\": \"{}\"}}",
            point_seed(seed, key)
        );
    }
    out.push_str("]}\n");
    check_or_seed("pyparity_rng.json", &out);
}

#[test]
fn fuzzgen_golden_pins_the_first_100_case_digests_for_seed_0() {
    let digests: Vec<u64> = (0..100).map(|i| fuzzgen::case_digest(0, i)).collect();
    // A rendered sample rides along so a digest mismatch in the Python
    // port can be debugged against the exact expected source text.
    let sample = fuzzgen::render(&fuzzgen::generate(0, 0));
    let escaped: String = sample
        .chars()
        .map(|c| match c {
            '"' => "\\\"".to_string(),
            '\\' => "\\\\".to_string(),
            '\n' => "\\n".to_string(),
            c => c.to_string(),
        })
        .collect();
    let out = format!(
        "{{\"seed\": \"0\", \"cases\": 100, \"digests\": {}, \"sample_case_0\": \"{escaped}\"}}\n",
        str_list(digests)
    );
    check_or_seed("pyparity_fuzzgen.json", &out);
}
