//! Golden-figure parity harness: pins every figure's and table's full
//! numeric output (via `api::Report`) as committed JSON snapshots, and
//! proves the parallel sweep engine reproduces the sequential oracle
//! bit for bit on every figure.
//!
//! Workflow:
//!
//! * Snapshots live in `tests/golden/<bench>.json` (one single-line
//!   JSON document each, in the `BENCH_hotpath.json` schema family).
//! * A **missing** snapshot is seeded from the current output and the
//!   test passes with a notice — so the first toolchain-bearing CI run
//!   writes the initial set (uploaded as artifacts; commit them).
//! * `UPDATE_GOLDEN=1 cargo test --test golden_figures` regenerates
//!   every snapshot in place (do this deliberately, with a diff review:
//!   a perf refactor must NOT bend a curve).
//! * On mismatch the fresh output is written next to the snapshot as
//!   `<bench>.json.new` and the test fails with both paths.
//!
//! The snapshots are generated with `Mode::Exact`, the default figure
//! seed, and no XLA artifacts — the same configuration
//! `memclos figures --all --json` uses out of the box.

use std::path::PathBuf;

use memclos::api::{Mode, Report, Tech};
use memclos::coordinator::{run_sweep_seq, ParallelSweep};
use memclos::figures::{self, fig5, fig6, fig9};

/// The figures' default seed (`FigOpts::default`).
const SEED: u64 = 0xC105;

/// Jobs for the parallel leg: at least 4, per the acceptance criterion.
fn parallel_jobs() -> usize {
    memclos::coordinator::default_jobs().max(4)
}

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

fn update_requested() -> bool {
    std::env::var("UPDATE_GOLDEN").map(|v| v == "1").unwrap_or(false)
}

/// Compare one report against its snapshot; seed the snapshot when
/// missing (or when `UPDATE_GOLDEN=1`). Returns a mismatch description
/// instead of panicking so every figure is checked in one run.
fn check_golden(report: &Report) -> Option<String> {
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).expect("creating tests/golden");
    let path = dir.join(format!("{}.json", report.bench()));
    let rendered = report.render();
    if update_requested() || !path.exists() {
        std::fs::write(&path, &rendered).expect("writing golden snapshot");
        eprintln!("seeded golden snapshot {}", path.display());
        return None;
    }
    let want = std::fs::read_to_string(&path).expect("reading golden snapshot");
    if want == rendered {
        return None;
    }
    let new_path = dir.join(format!("{}.json.new", report.bench()));
    std::fs::write(&new_path, &rendered).expect("writing .new snapshot");
    Some(format!(
        "{}: output diverges from {} (fresh output at {}; run with UPDATE_GOLDEN=1 to accept)",
        report.bench(),
        path.display(),
        new_path.display()
    ))
}

#[test]
fn golden_figures_parallel_equals_sequential_equals_snapshots() {
    let tech = Tech::default();
    // Two engines over the same configuration: the parallel one and the
    // jobs=1 sequential-oracle path.
    let par = ParallelSweep::new(Mode::Exact, &tech, parallel_jobs(), SEED);
    let seq = ParallelSweep::new(Mode::Exact, &tech, 1, SEED);
    let par_reports = figures::all_reports(&par).expect("parallel figure generation");
    let seq_reports = figures::all_reports(&seq).expect("sequential figure generation");

    // Parity: every figure's full numeric document is byte-identical
    // across job counts.
    assert_eq!(par_reports.len(), seq_reports.len());
    for (p, s) in par_reports.iter().zip(&seq_reports) {
        assert_eq!(p.bench(), s.bench());
        assert_eq!(
            p.render(),
            s.render(),
            "figure `{}` diverges between --jobs {} and the sequential oracle",
            p.bench(),
            parallel_jobs()
        );
    }

    // Snapshots: compare (or seed) every report.
    let mismatches: Vec<String> =
        par_reports.iter().filter_map(check_golden).collect();
    assert!(
        mismatches.is_empty(),
        "golden mismatches:\n  {}",
        mismatches.join("\n  ")
    );
}

#[test]
fn raw_sweep_parallel_equals_oracle_on_figure_points() {
    // Below the report layer: the PointResults themselves are
    // bit-identical between run_sweep_seq and ParallelSweep on the
    // fig 9/10 sweep, for both a closed-form and a sampling backend.
    let tech = Tech::default();
    let points = fig9::sweep_points();
    for mode in [Mode::Exact, Mode::Native { samples: 4_000 }] {
        let oracle = run_sweep_seq(&points, mode, &tech, SEED).unwrap();
        let par = ParallelSweep::new(mode, &tech, parallel_jobs(), SEED)
            .eval_points(&points)
            .unwrap();
        assert_eq!(oracle.len(), par.len());
        for (a, b) in oracle.iter().zip(&par) {
            assert_eq!(a.point, b.point, "{mode:?}: order");
            assert_eq!(
                a.mean_cycles.to_bits(),
                b.mean_cycles.to_bits(),
                "{mode:?}: point {:?}",
                a.point
            );
            assert_eq!(a.samples, b.samples);
            assert_eq!(a.backend, b.backend);
        }
    }
}

#[test]
fn contention_lab_joins_the_harness() {
    // The contention figure is part of `all_reports`, so the main test
    // above already pins `tests/golden/contention.json` and asserts
    // parallel == sequential on it. This checks the emitter contract on
    // an affordable grid: a report exists for every cell, names are
    // well-formed, and the uniform cells embed the legacy oracle's
    // numbers (`sim::network::run_contention`) bit for bit.
    use memclos::api::DesignPoint;
    use memclos::emulation::TopologyKind;
    use memclos::figures::contention::{cell_seed, eval_cells, report_rows, Cell};
    use memclos::sim::network::run_contention;
    use memclos::workload::TracePattern;

    let engine = ParallelSweep::new(Mode::Exact, &Tech::default(), parallel_jobs(), SEED);
    let point = memclos::coordinator::SweepPoint {
        kind: TopologyKind::Clos,
        tiles: 256,
        mem_kb: 128,
        k: 255,
    };
    let cells: Vec<Cell> = [
        (TracePattern::Uniform, 1usize),
        (TracePattern::Uniform, 8),
        (TracePattern::Zipf { theta: 1.2 }, 8),
        (TracePattern::PointerChase, 8),
    ]
    .iter()
    .map(|&(pattern, clients)| Cell { point, pattern, clients, accesses: 200 })
    .collect();
    let rows = eval_cells(&engine, &cells).unwrap();
    let report = report_rows(&rows);
    assert_eq!(report.bench(), "contention");
    assert_eq!(report.len(), cells.len());
    let rendered = report.render();
    for r in &rows {
        assert!(rendered.contains(&format!("\"name\": \"{}\"", r.name())));
    }

    let setup = DesignPoint::new(point.kind, point.tiles)
        .mem_kb(point.mem_kb)
        .k(point.k)
        .build()
        .unwrap();
    for (cell, row) in cells.iter().zip(&rows).filter(|(c, _)| {
        matches!(c.pattern, TracePattern::Uniform)
    }) {
        let legacy = run_contention(&setup, cell.clients, cell.accesses, cell_seed(SEED, cell));
        assert_eq!(
            row.stats.latency.mean().to_bits(),
            legacy.latency.mean().to_bits(),
            "uniform cell (c{}) diverged from the legacy oracle",
            cell.clients
        );
    }
}

#[test]
fn faults_figure_joins_the_harness() {
    // The faults figure is part of `all_reports`, so the main test
    // above already pins `tests/golden/faults.json` and asserts
    // parallel == sequential on it. This checks the emitter contract on
    // an affordable grid: a report row exists for every cell, names are
    // well-formed, and the fraction-0 uniform cell embeds the legacy
    // healthy oracle (`sim::network::run_contention`) bit for bit.
    use memclos::api::DesignPoint;
    use memclos::emulation::TopologyKind;
    use memclos::figures::contention::cell_seed;
    use memclos::figures::faults::{emulation_k, eval_cells, report_rows, Cell};
    use memclos::sim::network::run_contention;
    use memclos::workload::TracePattern;

    let engine = ParallelSweep::new(Mode::Exact, &Tech::default(), parallel_jobs(), SEED);
    let point = memclos::coordinator::SweepPoint {
        kind: TopologyKind::Clos,
        tiles: 256,
        mem_kb: 128,
        k: emulation_k(256),
    };
    let cells: Vec<Cell> = [
        (0u32, TracePattern::Uniform),
        (0, TracePattern::Zipf { theta: 1.2 }),
        (50, TracePattern::Uniform),
        (50, TracePattern::Zipf { theta: 1.2 }),
        (100, TracePattern::Uniform),
    ]
    .iter()
    .map(|&(frac_pm, pattern)| Cell { point, frac_pm, pattern, clients: 8, accesses: 200 })
    .collect();
    let rows = eval_cells(&engine, &cells).unwrap();
    let report = report_rows(&rows);
    assert_eq!(report.bench(), "faults");
    assert_eq!(report.len(), cells.len());
    let rendered = report.render();
    for r in &rows {
        assert!(rendered.contains(&format!("\"name\": \"{}\"", r.name())));
    }

    // The fraction-0 uniform cell IS the healthy legacy experiment.
    let setup = DesignPoint::new(point.kind, point.tiles)
        .mem_kb(point.mem_kb)
        .k(point.k)
        .build()
        .unwrap();
    let (cell, row) = cells
        .iter()
        .zip(&rows)
        .find(|(c, _)| c.frac_pm == 0 && matches!(c.pattern, TracePattern::Uniform))
        .unwrap();
    let legacy =
        run_contention(&setup, cell.clients, cell.accesses, cell_seed(SEED, &cell.inner()));
    assert_eq!(
        row.stats.latency.mean().to_bits(),
        legacy.latency.mean().to_bits(),
        "fraction-0 uniform cell diverged from the healthy oracle"
    );
    // Faulted rows report their fault census and retry counters.
    for r in rows.iter().filter(|r| r.frac_pm > 0) {
        assert!(r.dead_tiles > 0 || r.degraded_links > 0 || r.flaky_links > 0, "{r:?}");
        assert!(r.slowdown.is_finite() && r.p99_inflation.is_finite());
    }
}

#[test]
fn scale_figure_joins_the_harness() {
    // The scale figure is part of `all_reports`, so the main test above
    // already pins `tests/golden/scale.json` and asserts parallel ==
    // sequential on it (including the million-tile cells). This checks
    // the emitter contract on the affordable sizes, and that scale
    // cells — which ARE uniform contention cells — embed the legacy
    // oracle (`sim::network::run_contention`) bit for bit.
    use memclos::api::DesignPoint;
    use memclos::figures::contention::cell_seed;
    use memclos::figures::scale::{self, eval_points, FigScale};
    use memclos::sim::network::run_contention;

    let engine = ParallelSweep::new(Mode::Exact, &Tech::default(), parallel_jobs(), SEED);
    let cells: Vec<_> =
        scale::grid_cells().into_iter().filter(|c| c.point.tiles <= 4096).collect();
    let rows = eval_points(&engine, &cells).unwrap();
    let report = scale::report(&FigScale { rows: rows.clone() });
    assert_eq!(report.bench(), "scale");
    assert_eq!(report.len(), cells.len());
    let rendered = report.render();
    for r in &rows {
        assert!(rendered.contains(&format!("\"name\": \"{}\"", r.name())));
    }
    for (cell, row) in cells.iter().zip(&rows) {
        let setup = DesignPoint::new(cell.point.kind, cell.point.tiles)
            .mem_kb(cell.point.mem_kb)
            .k(cell.point.k)
            .build()
            .unwrap();
        let legacy =
            run_contention(&setup, cell.clients, cell.accesses, cell_seed(SEED, cell));
        assert_eq!(
            row.stats.latency.mean().to_bits(),
            legacy.latency.mean().to_bits(),
            "{}: scale cell diverged from the legacy contention oracle",
            row.name()
        );
    }
    // The table-era sizes stay table-feasible; the full grid's top end
    // (checked by the main snapshot test) is not.
    assert!(rows.iter().all(|r| r.table_feasible));
}

#[test]
fn fig5_fig6_combined_run_hits_the_plan_cache() {
    // Acceptance criterion: the repeated-point cache reports >= 1 hit
    // on the fig5+fig6 combined run (fig 6's 256 KB plans are a subset
    // of fig 5's grid).
    let engine = ParallelSweep::new(Mode::Exact, &Tech::default(), parallel_jobs(), SEED);
    fig5::generate_with(&engine).unwrap();
    let before = engine.cache_stats();
    fig6::generate_with(&engine).unwrap();
    let after = engine.cache_stats();
    assert!(
        after.hits >= before.hits + 1,
        "fig5+fig6 shared no plans: {before:?} -> {after:?}"
    );
    assert_eq!(
        after.misses, before.misses,
        "fig6 re-evaluated plans fig5 already produced"
    );
}

#[test]
fn fig9_fig10_fig11_share_the_latency_sweep() {
    // Figs 10 and 11 reuse fig 9's sweep points: on a shared engine
    // their latency evaluations are all cache hits.
    let engine = ParallelSweep::new(Mode::Exact, &Tech::default(), parallel_jobs(), SEED);
    fig9::generate_with(&engine).unwrap();
    let before = engine.cache_stats();
    figures::fig10::generate_with(&engine).unwrap();
    figures::fig11::generate_with(&engine).unwrap();
    let after = engine.cache_stats();
    assert_eq!(
        after.misses, before.misses,
        "figs 10/11 re-evaluated latency points fig 9 already produced"
    );
    assert!(after.hits > before.hits);
}
