//! Concurrency tests for the sweep engine's building blocks:
//!
//! * `coordinator::WorkQueue` — multi-producer/multi-consumer stress
//!   (no lost or duplicated items), close-while-popping semantics, and
//!   close racing producers.
//! * `coordinator::ParallelSweep` — the determinism property: random
//!   point sets produce bit-identical results at `--jobs 1` and
//!   `--jobs 8`, and both match the sequential oracle `run_sweep_seq`.
//! * `figures::contention` — the same property for the contention lab:
//!   a random pattern × clients cell grid is bit-identical at `--jobs
//!   1` and `--jobs 8` (each cell is one DES timeline; the engine only
//!   parallelises across cells).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use memclos::api::{Mode, Tech};
use memclos::coordinator::{run_sweep_seq, ParallelSweep, SweepPoint, WorkQueue};
use memclos::emulation::TopologyKind;
use memclos::util::prop::{forall, Config};
use memclos::util::rng::Rng;

#[test]
fn work_queue_mpmc_stress_no_lost_or_duplicated_items() {
    const PRODUCERS: u64 = 4;
    const CONSUMERS: usize = 4;
    const PER_PRODUCER: u64 = 2_000;
    // A small capacity forces constant backpressure hand-offs.
    let q = Arc::new(WorkQueue::<u64>::new(16));

    let producers: Vec<_> = (0..PRODUCERS)
        .map(|p| {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                for i in 0..PER_PRODUCER {
                    assert!(q.push(p * PER_PRODUCER + i), "queue closed early");
                }
            })
        })
        .collect();
    let consumers: Vec<_> = (0..CONSUMERS)
        .map(|_| {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = q.pop() {
                    got.push(v);
                }
                got
            })
        })
        .collect();

    for p in producers {
        p.join().unwrap();
    }
    q.close();
    assert!(q.is_closed());
    let mut all: Vec<u64> = Vec::new();
    for c in consumers {
        all.extend(c.join().unwrap());
    }
    // Every pushed value exactly once: no losses, no duplicates.
    all.sort_unstable();
    let expected: Vec<u64> = (0..PRODUCERS * PER_PRODUCER).collect();
    assert_eq!(all, expected);
    assert!(q.is_empty(), "queue drained");
}

#[test]
fn work_queue_close_releases_blocked_consumers() {
    let q = Arc::new(WorkQueue::<u64>::new(4));
    // Consumers block on the empty queue...
    let consumers: Vec<_> = (0..4)
        .map(|_| {
            let q = Arc::clone(&q);
            std::thread::spawn(move || q.pop())
        })
        .collect();
    std::thread::sleep(std::time::Duration::from_millis(50));
    // ...until close() wakes every one of them with None.
    q.close();
    for c in consumers {
        assert_eq!(c.join().unwrap(), None);
    }
}

#[test]
fn work_queue_close_racing_producers_loses_nothing_accepted() {
    // Producers race a closer: a push that returned true must be
    // delivered exactly once; a push that returned false is dropped.
    let q = Arc::new(WorkQueue::<u64>::new(8));
    let accepted = Arc::new(AtomicU64::new(0));

    let producers: Vec<_> = (0..4u64)
        .map(|p| {
            let q = Arc::clone(&q);
            let accepted = Arc::clone(&accepted);
            std::thread::spawn(move || {
                for i in 0..500 {
                    if q.push(p * 500 + i) {
                        accepted.fetch_add(1, Ordering::SeqCst);
                    }
                }
            })
        })
        .collect();
    let consumers: Vec<_> = (0..3)
        .map(|_| {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = q.pop() {
                    got.push(v);
                }
                got
            })
        })
        .collect();
    let closer = {
        let q = Arc::clone(&q);
        std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(2));
            q.close();
        })
    };

    closer.join().unwrap();
    for p in producers {
        p.join().unwrap();
    }
    let mut all: Vec<u64> = Vec::new();
    for c in consumers {
        all.extend(c.join().unwrap());
    }
    let n = all.len() as u64;
    all.sort_unstable();
    all.dedup();
    assert_eq!(all.len() as u64, n, "duplicated items");
    assert_eq!(n, accepted.load(Ordering::SeqCst), "accepted != delivered");
}

/// A random, duplicate-bearing, always-valid point set.
fn random_points(r: &mut Rng) -> Vec<SweepPoint> {
    let n = 3 + r.below(18) as usize;
    let mut points: Vec<SweepPoint> = Vec::with_capacity(n);
    for _ in 0..n {
        // ~1 in 3: repeat an earlier point (exercises the memo cache on
        // the parallel legs; the oracle evaluates it fresh — results
        // must still agree bitwise, proving the cache is transparent).
        if !points.is_empty() && r.below(3) == 0 {
            let dup = points[r.below(points.len() as u64) as usize];
            points.push(dup);
            continue;
        }
        let kind = if r.below(2) == 0 { TopologyKind::Clos } else { TopologyKind::Mesh };
        let tiles = *r.choose(&[256usize, 1024]);
        let mem_kb = *r.choose(&[64u32, 128]);
        let k = 1 + r.below(tiles as u64 - 1) as usize;
        points.push(SweepPoint { kind, tiles, mem_kb, k });
    }
    points
}

/// A random contention cell over small-but-real design points.
fn random_cell(r: &mut Rng) -> memclos::figures::contention::Cell {
    use memclos::workload::TracePattern;
    let tiles = *r.choose(&[256usize, 1024]);
    let kind = if r.below(2) == 0 { TopologyKind::Clos } else { TopologyKind::Mesh };
    let k = 1 + r.below(tiles as u64 - 1) as usize;
    let pattern = match r.below(5) {
        0 => TracePattern::Uniform,
        1 => TracePattern::Zipf { theta: 0.8 + r.f64() },
        2 => TracePattern::Stride { stride: 1 + r.below(1 << 17) },
        3 => TracePattern::PointerChase,
        _ => TracePattern::Phased { phases: 1 + r.below(6) as usize, frac: 0.05 + r.f64() * 0.4 },
    };
    memclos::figures::contention::Cell {
        point: SweepPoint { kind, tiles, mem_kb: 64, k },
        pattern,
        clients: 1 + r.below(12) as usize,
        accesses: 120,
    }
}

#[test]
fn contention_grid_jobs1_vs_jobs8_bitwise() {
    use memclos::figures::contention::eval_cells;
    // One random duplicate-bearing grid (the cells, not the RNG cases,
    // carry the randomness — both legs must agree bit for bit).
    let mut r = Rng::new(0xC047);
    let mut cells: Vec<memclos::figures::contention::Cell> =
        (0..10).map(|_| random_cell(&mut r)).collect();
    let dup = cells[3];
    cells.push(dup); // a repeated cell must evaluate identically too
    let tech = Tech::default();
    let seq = eval_cells(&ParallelSweep::new(Mode::Exact, &tech, 1, 0xAB), &cells).unwrap();
    let par = eval_cells(&ParallelSweep::new(Mode::Exact, &tech, 8, 0xAB), &cells).unwrap();
    assert_eq!(seq.len(), par.len());
    for (i, (a, b)) in seq.iter().zip(&par).enumerate() {
        assert_eq!(a.pattern, b.pattern, "cell {i}");
        assert_eq!(a.clients, b.clients, "cell {i}");
        assert_eq!(
            a.stats.latency.mean().to_bits(),
            b.stats.latency.mean().to_bits(),
            "cell {i} ({}-c{}): mean diverged across job counts",
            a.pattern,
            a.clients
        );
        assert_eq!(a.stats.latency.count(), b.stats.latency.count(), "cell {i}");
        assert_eq!(a.stats.dist, b.stats.dist, "cell {i}");
        assert_eq!(a.stats.c_cont.to_bits(), b.stats.c_cont.to_bits(), "cell {i}");
        assert_eq!(a.stats.wait.mean().to_bits(), b.stats.wait.mean().to_bits(), "cell {i}");
        assert_eq!(a.stats.makespan, b.stats.makespan, "cell {i}");
        assert_eq!(
            a.stats.port_util_max.to_bits(),
            b.stats.port_util_max.to_bits(),
            "cell {i}"
        );
    }
    // The duplicated cell's two rows are bit-identical to each other.
    let (x, y) = (&seq[3], &seq[cells.len() - 1]);
    assert_eq!(x.stats.latency.mean().to_bits(), y.stats.latency.mean().to_bits());
    assert_eq!(x.stats.dist, y.stats.dist);
}

#[test]
fn parallel_sweep_determinism_on_random_point_sets() {
    forall(
        Config { cases: 10, base_seed: 0xD17 },
        |r| (random_points(r), r.next_u64()),
        |(points, seed)| {
            for mode in [Mode::Exact, Mode::Native { samples: 2_000 }] {
                let tech = Tech::default();
                let oracle =
                    run_sweep_seq(points, mode, &tech, *seed).map_err(|e| e.to_string())?;
                for jobs in [1usize, 8] {
                    let par = ParallelSweep::new(mode, &tech, jobs, *seed)
                        .eval_points(points)
                        .map_err(|e| e.to_string())?;
                    if par.len() != oracle.len() {
                        return Err(format!("{mode:?} jobs={jobs}: length mismatch"));
                    }
                    for (i, (a, b)) in oracle.iter().zip(&par).enumerate() {
                        if a.point != b.point {
                            return Err(format!("{mode:?} jobs={jobs}: order differs at {i}"));
                        }
                        if a.mean_cycles.to_bits() != b.mean_cycles.to_bits()
                            || a.samples != b.samples
                            || a.backend != b.backend
                        {
                            return Err(format!(
                                "{mode:?} jobs={jobs}: point {:?} diverges ({} vs {})",
                                a.point, a.mean_cycles, b.mean_cycles
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}
