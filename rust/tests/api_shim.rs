//! The `memclos::api` shim contract: the typed [`DesignPoint`] builder
//! must be **bit-identical** to the legacy `EmulationSetup::build`
//! positional constructor across random design points — same rank
//! LUT, same expected latency, same kernel-parameter encoding — so
//! call sites could migrate without any numeric drift.

use memclos::api::{AddrStream, DesignPoint, Evaluator, LatencyBackend, Mode, NativeMcBackend};
use memclos::emulation::{EmulationSetup, TopologyKind};
use memclos::netmodel::NetParams;
use memclos::tech::{ChipTech, InterposerTech};
use memclos::util::prop::{check, ensure};
use memclos::util::rng::Rng;

#[test]
fn builder_is_bit_identical_to_legacy_build() {
    check(
        |r: &mut Rng| {
            let kind = if r.chance(0.5) { TopologyKind::Clos } else { TopologyKind::Mesh };
            let tiles = *r.choose(&[256usize, 1024]);
            let mem_kb = *r.choose(&[64u32, 128, 256]);
            let k = 1 + r.below((tiles - 1) as u64) as usize;
            // Perturb the tech so equality is not just "both used the
            // paper defaults".
            let t_mem = 1.0 + r.below(4) as f64;
            let t_switch = 1.0 + r.below(3) as f64;
            let route_open = r.chance(0.3);
            (kind, tiles, mem_kb, k, t_mem, t_switch, route_open)
        },
        |&(kind, tiles, mem_kb, k, t_mem, t_switch, route_open)| {
            let net = NetParams { t_mem, t_switch, route_open, ..NetParams::default() };
            let chip = ChipTech::default();
            let ip = InterposerTech::default();

            let legacy =
                EmulationSetup::build(kind, tiles, mem_kb, k, net, &chip, &ip).unwrap();
            let built = DesignPoint::new(kind, tiles)
                .mem_kb(mem_kb)
                .k(k)
                .net(net)
                .chip(chip)
                .interposer(ip)
                .build()
                .unwrap();

            ensure(built.map == legacy.map, "address maps differ")?;
            ensure(
                built.rank_latencies().len() == legacy.rank_latencies().len(),
                "LUT lengths differ",
            )?;
            for (r, (a, b)) in
                built.rank_latencies().iter().zip(legacy.rank_latencies()).enumerate()
            {
                ensure(
                    a.to_bits() == b.to_bits(),
                    format!("rank {r}: builder {a} != legacy {b}"),
                )?;
            }
            ensure(
                built.expected_latency().to_bits() == legacy.expected_latency().to_bits(),
                "expected latency differs",
            )?;
            ensure(
                built.kernel_params() == legacy.kernel_params(),
                "kernel params differ",
            )
        },
    );
}

#[test]
fn full_emulation_is_the_default_k() {
    // The builder's paper default (`k = tiles - 1`) matches an explicit
    // full emulation through the legacy shim.
    for tiles in [256usize, 1024] {
        let dp = DesignPoint::clos(tiles).build().unwrap();
        let legacy = EmulationSetup::build(
            TopologyKind::Clos,
            tiles,
            128,
            tiles - 1,
            NetParams::default(),
            &ChipTech::default(),
            &InterposerTech::default(),
        )
        .unwrap();
        assert_eq!(dp.expected_latency().to_bits(), legacy.expected_latency().to_bits());
    }
}

#[test]
fn evaluator_backends_agree_on_one_point() {
    // Exact through the Evaluator == EmulationSetup::expected_latency,
    // and the native MC backend lands within sampling error of it.
    let setup = DesignPoint::clos(1024).k(767).build().unwrap();
    let exact = Evaluator::new(Mode::Exact).unwrap();
    let e = exact.evaluate(&setup, &exact.stream(0)).unwrap();
    assert_eq!(e.mean_cycles.to_bits(), setup.expected_latency().to_bits());

    let mc = NativeMcBackend.evaluate(&setup, &AddrStream::new(50_000, 3)).unwrap();
    assert!((mc.mean_cycles - e.mean_cycles).abs() / e.mean_cycles < 0.02);
}
