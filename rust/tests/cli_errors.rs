//! The CLI exit-code contract, end to end: every command-line misuse is
//! a typed [`UsageError`] mapped to exit code 2 with a field-named
//! message; runtime failures keep exit code 1; nothing panics on bad
//! input. Drives the real driver ([`memclos::cli::driver::run`])
//! in-process — the same code path as the binary.

use memclos::cli::{driver, exit_code, UsageError};

fn run(line: &str) -> anyhow::Result<()> {
    driver::run(line.split_whitespace().map(str::to_string).collect())
}

fn usage_err(line: &str) -> anyhow::Error {
    let err = run(line).expect_err(&format!("`{line}` must fail"));
    assert_eq!(exit_code(&err), 2, "`{line}` must be misuse (exit 2): {err:#}");
    assert!(
        err.chain().any(|c| c.downcast_ref::<UsageError>().is_some()),
        "`{line}` must carry a typed UsageError: {err:#}"
    );
    err
}

#[test]
fn misuse_matrix_is_typed_with_exit_code_2() {
    // (command line, fragment the message must name)
    for (line, fragment) in [
        ("frobnicate", "unknown command"),
        ("figure", "figure number required"),
        ("figure bogus", "no figure bogus"),
        ("figures", "figures --all"),
        ("figures 5", "figure 5"),
        ("tables --which 9", "no table 9"),
        ("latency --tiles abc", "flag --tiles"),
        ("latency --topo ring", "ring"),
        ("latency --samples", "expects a value"),
        ("run", "program name required"),
        ("run nosuchprog", "unknown program `nosuchprog`"),
        ("contention --clients 0", "--clients 0"),
        ("contention --clients x", "--clients: cannot parse `x`"),
        ("contention --samples 0", "--samples 0"),
        ("contention --pattern warp", "unknown pattern"),
        ("loadgen", "--addr"),
        ("loadgen --self-host --clients 0", "--clients 0"),
        ("loadgen --self-host --requests 0", "--requests 0"),
        ("latency --config /nonexistent/memclos.toml", "reading config"),
        ("serve --queue-depth abc", "flag --queue-depth"),
        ("fuzz --cases 0", "--cases 0"),
        ("fuzz --cases abc", "flag --cases"),
        ("fuzz --replay x.cc --cases 5", "conflicts with --cases"),
        ("fuzz --shrink --no-shrink", "--shrink conflicts with --no-shrink"),
        ("fuzz --max-failures 0", "--max-failures 0"),
        ("snapshot", "needs a subcommand"),
        ("snapshot bogus", "unknown snapshot subcommand `bogus`"),
        ("snapshot save", "needs --program"),
        ("snapshot save --program sieve", "needs --at"),
        ("snapshot save --program sieve --at 0", "needs --at"),
        ("snapshot save --program nosuch --at 100", "unknown program `nosuch`"),
        ("snapshot save --program sieve --at 100 --backend weird", "--backend"),
        ("snapshot resume", "needs --in"),
        ("run sieve --tier warp", "flag --tier"),
        ("run sieve --legacy --tier jit", "conflicts with --tier"),
    ] {
        let err = usage_err(line);
        let msg = format!("{err:#}");
        assert!(msg.contains(fragment), "`{line}`: expected `{fragment}` in `{msg}`");
    }
}

#[test]
fn design_point_validation_is_a_field_named_failure() {
    // An invalid design point is caught by the builder with a
    // field-named message. It is a nonzero failure either way; the
    // message must say WHICH field.
    let err = run("latency --tiles 64 --k 64").expect_err("k >= tiles must fail");
    assert!(format!("{err:#}").contains("`k`"), "{err:#}");
    let err = run("sweep --mem 0").expect_err("mem 0 must fail");
    assert!(format!("{err:#}").contains("`mem_kb`"), "{err:#}");
}

#[test]
fn corrupt_snapshots_are_runtime_failures_not_misuse() {
    // A snapshot that exists but is garbage is a RUNTIME failure (exit
    // 1, a typed SnapshotError in the chain) — the command line itself
    // was fine. Same for a missing file.
    let dir = std::env::temp_dir().join("memclos-cli-errors-test");
    std::fs::create_dir_all(&dir).unwrap();
    let garbage = dir.join("garbage.snap");
    std::fs::write(&garbage, b"MCSSnot really a snapshot").unwrap();
    let err = run(&format!("snapshot resume --in {}", garbage.display()))
        .expect_err("garbage snapshot must fail");
    assert_eq!(exit_code(&err), 1, "corrupt file is runtime, not misuse: {err:#}");
    assert!(format!("{err:#}").contains("snapshot"), "{err:#}");

    let missing = dir.join("does-not-exist.snap");
    let err = run(&format!("snapshot resume --in {}", missing.display()))
        .expect_err("missing snapshot must fail");
    assert_eq!(exit_code(&err), 1, "{err:#}");
    assert!(format!("{err:#}").contains("reading"), "{err:#}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn valid_commands_still_succeed() {
    // The misuse plumbing must not break the happy path: cheap,
    // deterministic commands run clean through the same driver.
    run("tables --which 3").expect("tables");
    run("area --topo clos --tiles 256").expect("area");
    run("latency --mode exact --tiles 256 --k 63 --json").expect("latency");
}

#[test]
fn explicit_jit_tier_is_honest_about_the_host() {
    // `--tier jit` is an explicit request, so it must either run (on
    // hosts the baseline compiler targets) or fail as a typed RUNTIME
    // error (exit 1) naming the tier — never a silent fallback, and
    // never command-line misuse.
    if memclos::isa::jit::available() {
        run("run sum_squares --tier jit").expect("jit tier runs on a supported host");
        run("run sum_squares --tier auto").expect("auto tier");
    } else {
        let err = run("run sum_squares --tier jit").expect_err("jit tier must refuse");
        assert_eq!(exit_code(&err), 1, "unsupported host is runtime, not misuse: {err:#}");
        assert!(format!("{err:#}").contains("JIT tier unsupported"), "{err:#}");
        // `auto` degrades to the fast tier instead of failing.
        run("run sum_squares --tier auto").expect("auto tier falls back");
    }
}

#[test]
fn help_never_fails() {
    run("").expect("bare invocation prints help");
    run("help").expect("help command");
    run("latency --help").expect("--help flag");
}
