//! End-to-end corpus execution on both memory systems (ISSUE 3
//! satellite): every program with a pinned expected value must compute
//! it on both machines, emulated cycles must dominate direct cycles at
//! full-scale design points, and the decoded interpreter must agree
//! bit-for-bit with the legacy oracle on real (control-flow-heavy)
//! programs. The ISSUE 10 rows extend the table a tier upward: the
//! baseline JIT must match the fast tier's stats, results and error
//! strings at the same full-emulation points (skipped, with a notice,
//! on hosts the JIT does not target).

use memclos::api::DesignPoint;
use memclos::cc::corpus;
use memclos::emulation::{SequentialMachine, TopologyKind};
use memclos::isa::decode::{predecode, FastMachine};
use memclos::isa::interp::{DirectMemory, EmulatedChannelMemory, Machine};
use memclos::isa::jit::{self, JitMachine};
use memclos::isa::Inst;
use memclos::workload::measured::{CompiledCorpus, JitCorpus};

#[test]
fn corpus_expected_values_on_both_machines() {
    let compiled = CompiledCorpus::compile().unwrap();
    let seq = SequentialMachine::with_measured_dram(1);
    let pinned: Vec<&str> = corpus::all()
        .iter()
        .filter(|p| p.expected.is_some())
        .map(|p| p.name)
        .collect();
    assert!(pinned.len() >= 3, "corpus should pin several results: {pinned:?}");

    for (kind, tiles) in [(TopologyKind::Clos, 1024usize), (TopologyKind::Clos, 4096)] {
        let setup = DesignPoint::new(kind, tiles)
            .mem_kb(128)
            .k(tiles - 1)
            .build()
            .unwrap();
        let m = compiled.measure(&setup, seq).unwrap();
        assert_eq!(m.runs.len(), corpus::all().len());
        for run in &m.runs {
            // measure() verifies agreement + expected internally;
            // re-assert the satellite's claims explicitly.
            assert_eq!(
                run.direct_result, run.emulated_result,
                "{} at {kind:?}/{tiles}",
                run.name
            );
            if let Some(want) = run.expected {
                assert_eq!(run.direct_result, want, "{} at {kind:?}/{tiles}", run.name);
            }
            // Full-scale emulation is never cheaper than the
            // sequential machine on a global-touching program.
            assert!(
                run.emulated.cycles >= run.direct.cycles,
                "{} at {kind:?}/{tiles}: emulated {} < direct {}",
                run.name,
                run.emulated.cycles,
                run.direct.cycles
            );
            assert!(run.emulated.instructions > run.direct.instructions, "{}", run.name);
        }
        // Aggregate slowdown sits in the paper's broad band at full
        // emulation.
        let sd = m.slowdown();
        assert!(
            sd > 1.0 && sd < 6.0,
            "{kind:?}/{tiles}: aggregate measured slowdown {sd}"
        );
    }
}

#[test]
fn decoded_is_bit_identical_to_legacy_on_the_corpus() {
    let compiled = CompiledCorpus::compile().unwrap();
    let seq = SequentialMachine::paper_figures(false);
    let setup = DesignPoint::clos(1024).mem_kb(128).k(255).build().unwrap();
    for p in &compiled.programs {
        // Direct backend.
        let mut lm = DirectMemory::new(seq, 1 << 20);
        let mut legacy = Machine::new(&mut lm, 1 << 16);
        let ls = legacy.run(&p.direct_code).unwrap();
        let mut fm = DirectMemory::new(seq, 1 << 20);
        let mut fast = FastMachine::new(&mut fm, 1 << 16);
        let fs = fast.run(&p.direct).unwrap();
        assert_eq!(ls, fs, "{}: direct stats diverge", p.name);
        assert_eq!(legacy.reg(0), fast.reg(0), "{}", p.name);

        // Emulated backend.
        let mut lem = EmulatedChannelMemory::new(setup.clone());
        let mut elegacy = Machine::new(&mut lem, 1 << 16);
        let els = elegacy.run(&p.emulated_code).unwrap();
        let mut fem = EmulatedChannelMemory::new(setup.clone());
        let mut efast = FastMachine::new(&mut fem, 1 << 16);
        let efs = efast.run(&p.emulated).unwrap();
        assert_eq!(els, efs, "{}: emulated stats diverge", p.name);
        assert_eq!(elegacy.reg(0), efast.reg(0), "{}", p.name);

        // The fused macro-ops preserve the §7.3 accounting: the
        // emulated stream executes +2 instructions per load and +3 per
        // store over the direct stream.
        assert!(efs.global_memory > fs.global_memory, "{}", p.name);
        assert_eq!(efs.global_accesses, fs.global_accesses, "{}", p.name);
    }
}

#[test]
fn jit_is_bit_identical_to_fast_at_full_emulation_points() {
    if !jit::available() {
        eprintln!("skipping: JIT tier unavailable on this host");
        return;
    }
    let compiled = CompiledCorpus::compile().unwrap();
    let jitted = JitCorpus::compile(&compiled).unwrap();
    let seq = SequentialMachine::with_measured_dram(1);
    for (kind, tiles) in [(TopologyKind::Clos, 1024usize), (TopologyKind::Clos, 4096)] {
        let setup = DesignPoint::new(kind, tiles)
            .mem_kb(128)
            .k(tiles - 1)
            .build()
            .unwrap();
        let fast = compiled.measure(&setup, seq).unwrap();
        let native = jitted.measure(&setup, seq).unwrap();
        assert_eq!(fast.runs.len(), native.runs.len());
        assert_eq!(fast.direct_cycles, native.direct_cycles, "{kind:?}/{tiles}");
        assert_eq!(fast.emulated_cycles, native.emulated_cycles, "{kind:?}/{tiles}");
        for (f, j) in fast.runs.iter().zip(&native.runs) {
            assert_eq!(f.name, j.name);
            assert_eq!(f.direct, j.direct, "{} at {kind:?}/{tiles}: direct stats", f.name);
            assert_eq!(f.emulated, j.emulated, "{} at {kind:?}/{tiles}: emulated stats", f.name);
            assert_eq!(f.direct_result, j.direct_result, "{} at {kind:?}/{tiles}", f.name);
            assert_eq!(f.emulated_result, j.emulated_result, "{} at {kind:?}/{tiles}", f.name);
        }
    }
}

#[test]
fn jit_error_strings_match_fast_on_trap_and_control_flow_programs() {
    if !jit::available() {
        eprintln!("skipping: JIT tier unavailable on this host");
        return;
    }
    // The hand-written trap catalogue from tests/fuzz.rs, plus a
    // looping program (step limit) and negative local indices — each
    // run jit-vs-fast on fresh direct memories with a tight step
    // limit; stats, registers and error STRINGS must be identical.
    let programs: Vec<Vec<Inst>> = vec![
        vec![Inst::Jump { offset: 100 }],
        vec![Inst::BranchZ { c: 0, offset: 7 }, Inst::Halt],
        vec![Inst::Call { target: 9999 }, Inst::Halt],
        vec![Inst::Nop, Inst::Nop], // falls off the end
        vec![Inst::Ret],
        vec![Inst::LoadLocal { d: 0, a: 0, off: 1000 }, Inst::Halt],
        vec![Inst::StoreLocal { s: 0, a: 0, off: 1000 }, Inst::Halt],
        vec![Inst::Jump { offset: 0 }], // spins to the step limit
        // Negative local index via a register.
        vec![
            Inst::LoadImm { d: 1, imm: -5 },
            Inst::LoadLocal { d: 0, a: 1, off: 0 },
            Inst::Halt,
        ],
        // Call/ret with real work: triangular sum via a helper
        // (branch offsets are pc-relative: target = pc + offset).
        vec![
            Inst::LoadImm { d: 1, imm: 10 },
            Inst::LoadImm { d: 0, imm: 0 },
            Inst::BranchZ { c: 1, offset: 4 }, // 2 -> 6 (Halt) when r1 == 0
            Inst::Call { target: 7 },         // helper: r0 += r1
            Inst::AddI { d: 1, a: 1, imm: -1 },
            Inst::Jump { offset: -3 }, // 5 -> 2
            Inst::Halt,
            Inst::Add { d: 0, a: 0, b: 1 },
            Inst::Ret,
        ],
    ];
    for (pi, prog) in programs.iter().enumerate() {
        let decoded = predecode(prog).unwrap_or_else(|e| panic!("program {pi}: predecode: {e}"));
        let native = jit::compile(&decoded).unwrap();

        let mut fmem = DirectMemory::new(SequentialMachine::paper_figures(false), 1 << 12);
        let mut fast = FastMachine::new(&mut fmem, 64);
        fast.max_steps = 10_000;
        let fres = fast.run(&decoded);

        let mut jmem = DirectMemory::new(SequentialMachine::paper_figures(false), 1 << 12);
        let mut jm = JitMachine::new(&mut jmem, 64);
        jm.max_steps = 10_000;
        let jres = jm.run(&native);

        match (fres, jres) {
            (Ok(fs), Ok(js)) => {
                assert_eq!(fs, js, "program {pi}: stats diverge");
                assert_eq!(fast.regs(), jm.regs(), "program {pi}: registers diverge");
            }
            (Err(fe), Err(je)) => {
                assert_eq!(
                    fe.to_string(),
                    je.to_string(),
                    "program {pi}: error strings diverge"
                );
            }
            (f, j) => panic!("program {pi}: outcome diverges: fast {f:?} vs jit {j:?}"),
        }
    }
}
