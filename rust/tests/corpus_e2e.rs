//! End-to-end corpus execution on both memory systems (ISSUE 3
//! satellite): every program with a pinned expected value must compute
//! it on both machines, emulated cycles must dominate direct cycles at
//! full-scale design points, and the decoded interpreter must agree
//! bit-for-bit with the legacy oracle on real (control-flow-heavy)
//! programs.

use memclos::api::DesignPoint;
use memclos::cc::corpus;
use memclos::emulation::{SequentialMachine, TopologyKind};
use memclos::isa::decode::FastMachine;
use memclos::isa::interp::{DirectMemory, EmulatedChannelMemory, Machine};
use memclos::workload::measured::CompiledCorpus;

#[test]
fn corpus_expected_values_on_both_machines() {
    let compiled = CompiledCorpus::compile().unwrap();
    let seq = SequentialMachine::with_measured_dram(1);
    let pinned: Vec<&str> = corpus::all()
        .iter()
        .filter(|p| p.expected.is_some())
        .map(|p| p.name)
        .collect();
    assert!(pinned.len() >= 3, "corpus should pin several results: {pinned:?}");

    for (kind, tiles) in [(TopologyKind::Clos, 1024usize), (TopologyKind::Clos, 4096)] {
        let setup = DesignPoint::new(kind, tiles)
            .mem_kb(128)
            .k(tiles - 1)
            .build()
            .unwrap();
        let m = compiled.measure(&setup, seq).unwrap();
        assert_eq!(m.runs.len(), corpus::all().len());
        for run in &m.runs {
            // measure() verifies agreement + expected internally;
            // re-assert the satellite's claims explicitly.
            assert_eq!(
                run.direct_result, run.emulated_result,
                "{} at {kind:?}/{tiles}",
                run.name
            );
            if let Some(want) = run.expected {
                assert_eq!(run.direct_result, want, "{} at {kind:?}/{tiles}", run.name);
            }
            // Full-scale emulation is never cheaper than the
            // sequential machine on a global-touching program.
            assert!(
                run.emulated.cycles >= run.direct.cycles,
                "{} at {kind:?}/{tiles}: emulated {} < direct {}",
                run.name,
                run.emulated.cycles,
                run.direct.cycles
            );
            assert!(run.emulated.instructions > run.direct.instructions, "{}", run.name);
        }
        // Aggregate slowdown sits in the paper's broad band at full
        // emulation.
        let sd = m.slowdown();
        assert!(
            sd > 1.0 && sd < 6.0,
            "{kind:?}/{tiles}: aggregate measured slowdown {sd}"
        );
    }
}

#[test]
fn decoded_is_bit_identical_to_legacy_on_the_corpus() {
    let compiled = CompiledCorpus::compile().unwrap();
    let seq = SequentialMachine::paper_figures(false);
    let setup = DesignPoint::clos(1024).mem_kb(128).k(255).build().unwrap();
    for p in &compiled.programs {
        // Direct backend.
        let mut lm = DirectMemory::new(seq, 1 << 20);
        let mut legacy = Machine::new(&mut lm, 1 << 16);
        let ls = legacy.run(&p.direct_code).unwrap();
        let mut fm = DirectMemory::new(seq, 1 << 20);
        let mut fast = FastMachine::new(&mut fm, 1 << 16);
        let fs = fast.run(&p.direct).unwrap();
        assert_eq!(ls, fs, "{}: direct stats diverge", p.name);
        assert_eq!(legacy.reg(0), fast.reg(0), "{}", p.name);

        // Emulated backend.
        let mut lem = EmulatedChannelMemory::new(setup.clone());
        let mut elegacy = Machine::new(&mut lem, 1 << 16);
        let els = elegacy.run(&p.emulated_code).unwrap();
        let mut fem = EmulatedChannelMemory::new(setup.clone());
        let mut efast = FastMachine::new(&mut fem, 1 << 16);
        let efs = efast.run(&p.emulated).unwrap();
        assert_eq!(els, efs, "{}: emulated stats diverge", p.name);
        assert_eq!(elegacy.reg(0), efast.reg(0), "{}", p.name);

        // The fused macro-ops preserve the §7.3 accounting: the
        // emulated stream executes +2 instructions per load and +3 per
        // store over the direct stream.
        assert!(efs.global_memory > fs.global_memory, "{}", p.name);
        assert_eq!(efs.global_accesses, fs.global_accesses, "{}", p.name);
    }
}
