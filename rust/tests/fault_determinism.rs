//! Fault-subsystem property tests (the PR's standing invariants):
//!
//! 1. **Determinism** — the same `(plan, seed)` materialises the same
//!    faults, routing tables and DES bits at any `--jobs` count.
//! 2. **The empty-plan oracle rule** — a design point built with an
//!    empty [`FaultPlan`] is bit-identical to one built with no plan at
//!    all: same rank LUT, same expected latency, same DES summaries,
//!    same validation error strings.
//! 3. **Typed failures** — killed primaries, duplicate dead tiles,
//!    out-of-range fractions, capacity violations and unreachable
//!    destinations are all typed errors, never panics.

use memclos::api::{DesignPoint, Mode, Tech};
use memclos::coordinator::{ParallelSweep, SweepPoint};
use memclos::emulation::TopologyKind;
use memclos::fault::{FaultError, FaultMap, FaultPlan, FaultState, PortFault};
use memclos::figures::faults::{emulation_k, eval_cells, Cell};
use memclos::sim::contention::{run_scenario, Workload};
use memclos::sim::network::{run_contention, NetworkSim};
use memclos::topology::RoutingTable;
use memclos::workload::TracePattern;

/// The affordable faulted design point most tests share: 256 tiles at
/// k = 224, leaving dead-tile slack.
fn faulted_point(plan: FaultPlan) -> DesignPoint {
    DesignPoint::clos(256).mem_kb(128).k(emulation_k(256)).faults(plan)
}

#[test]
fn same_plan_and_seed_rebuild_identical_faults_and_lut_bits() {
    let plan = FaultPlan::fraction(0.06, 77);
    let a = faulted_point(plan.clone()).build().unwrap();
    let b = faulted_point(plan).build().unwrap();
    let fa = a.fault.as_ref().expect("plan materialised");
    let fb = b.fault.as_ref().expect("plan materialised");
    assert_eq!(fa.map, fb.map, "fault maps diverged across rebuilds");
    assert_eq!(fa.rank_tile, fb.rank_tile, "rank remap diverged");
    assert_eq!(a.rank_latencies().len(), b.rank_latencies().len());
    for (x, y) in a.rank_latencies().iter().zip(b.rank_latencies()) {
        assert_eq!(x.to_bits(), y.to_bits(), "rank LUT diverged");
    }
    assert_eq!(a.expected_latency().to_bits(), b.expected_latency().to_bits());
}

#[test]
fn fault_avoiding_routing_tables_are_deterministic() {
    let setup = faulted_point(FaultPlan::fraction(0.08, 3)).build().unwrap();
    let map = &setup.fault.as_ref().unwrap().map;
    assert!(map.failed_links > 0, "want failed links at 8% (got {map:?})");
    let g = setup.topo.graph();
    let rt1 = RoutingTable::build_avoiding(g, &map.failed_ports());
    let rt2 = RoutingTable::build_avoiding(g, &map.failed_ports());
    assert_eq!(rt1, rt2, "build_avoiding is not deterministic");
    // And the empty mask is bitwise the healthy build.
    let healthy_mask = vec![false; map.failed_ports().len()];
    assert_eq!(
        RoutingTable::build_avoiding(g, &healthy_mask),
        RoutingTable::build(g),
        "all-healthy mask diverged from the plain build"
    );
}

#[test]
fn faulted_des_is_jobs_invariant() {
    // Same (plan, seed) -> identical DES bits whether the figure grid
    // runs sequentially or on 8 workers.
    let point = SweepPoint {
        kind: TopologyKind::Clos,
        tiles: 256,
        mem_kb: 128,
        k: emulation_k(256),
    };
    let cells: Vec<Cell> = [0u32, 50, 100]
        .iter()
        .flat_map(|&frac_pm| {
            [TracePattern::Uniform, TracePattern::Zipf { theta: 1.2 }].map(|pattern| Cell {
                point,
                frac_pm,
                pattern,
                clients: 8,
                accesses: 150,
            })
        })
        .collect();
    let seq = eval_cells(&ParallelSweep::new(Mode::Exact, &Tech::default(), 1, 9), &cells)
        .unwrap();
    let par = eval_cells(&ParallelSweep::new(Mode::Exact, &Tech::default(), 8, 9), &cells)
        .unwrap();
    assert_eq!(seq.len(), par.len());
    for (a, b) in seq.iter().zip(&par) {
        assert_eq!(a.frac_pm, b.frac_pm);
        assert_eq!(a.dead_tiles, b.dead_tiles);
        assert_eq!(a.failed_links, b.failed_links);
        assert_eq!(a.stats.latency.mean().to_bits(), b.stats.latency.mean().to_bits());
        assert_eq!(a.stats.dist, b.stats.dist);
        assert_eq!(a.stats.retries, b.stats.retries);
        assert_eq!(a.stats.timeouts, b.stats.timeouts);
        assert_eq!(a.stats.makespan, b.stats.makespan);
    }
}

#[test]
fn empty_plan_is_bit_identical_to_no_plan() {
    let bare = DesignPoint::clos(256).mem_kb(128).k(255).build().unwrap();
    let empty = DesignPoint::clos(256)
        .mem_kb(128)
        .k(255)
        .faults(FaultPlan::none())
        .build()
        .unwrap();
    assert!(empty.fault.is_none(), "an empty plan must never materialise");
    for (x, y) in bare.rank_latencies().iter().zip(empty.rank_latencies()) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    assert_eq!(bare.expected_latency().to_bits(), empty.expected_latency().to_bits());
    for r in 0..255 {
        assert_eq!(bare.tile_of_rank(r), empty.tile_of_rank(r));
    }
    // DES summaries: the scenario engine on the empty-plan setup IS the
    // legacy run_contention experiment, bit for bit.
    let stats = run_scenario(&empty, 8, 200, 7, Workload::SharedUniform).unwrap();
    let legacy = run_contention(&bare, 8, 200, 7);
    assert_eq!(stats.latency.count(), legacy.latency.count());
    assert_eq!(stats.latency.mean().to_bits(), legacy.latency.mean().to_bits());
    assert_eq!(stats.inflation.to_bits(), legacy.inflation.to_bits());
    assert_eq!(stats.retries, 0);
    assert_eq!(stats.timeouts, 0);
}

#[test]
fn empty_plan_preserves_validation_error_strings() {
    // The oracle rule covers the failure paths too: a builder error
    // reads identically with and without an empty plan attached.
    let bare = DesignPoint::clos(256).mem_kb(128).k(0).build().unwrap_err().to_string();
    let empty = DesignPoint::clos(256)
        .mem_kb(128)
        .k(0)
        .faults(FaultPlan::none())
        .build()
        .unwrap_err()
        .to_string();
    assert_eq!(bare, empty);
}

#[test]
fn fault_plan_misuse_is_a_field_named_error() {
    for (plan, needle) in [
        (FaultPlan::fraction(1.5, 1), "fault.dead_tile_frac"),
        (FaultPlan { dead_tiles: vec![3, 3], ..FaultPlan::none() }, "duplicate"),
        (FaultPlan { dead_tiles: vec![2048], ..FaultPlan::none() }, "out of range"),
        (FaultPlan { dead_tiles: vec![0], ..FaultPlan::none() }, "primary"),
    ] {
        let err = faulted_point(plan).build().unwrap_err().to_string();
        assert!(err.contains(needle), "error `{err}` does not mention `{needle}`");
    }
    // Mesh: the primary lives at the centre block, not tile 0.
    let err = DesignPoint::new(TopologyKind::Mesh, 1024)
        .mem_kb(128)
        .k(900)
        .faults(FaultPlan { dead_tiles: vec![576], ..FaultPlan::none() })
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("primary"), "{err}");
    // Full emulation has zero dead-tile slack: any dead tile violates
    // the capacity-degradation rule.
    let err = DesignPoint::clos(256)
        .mem_kb(128)
        .k(255)
        .faults(FaultPlan { dead_tiles: vec![5], ..FaultPlan::none() })
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("alive"), "{err}");
}

/// A hand-built fault state severing every link (sampled plans can
/// never do this — the heal rule — so this is the only way to reach
/// the unreachable paths).
fn severed_state(setup: &memclos::emulation::EmulationSetup) -> FaultState {
    let num_ports = setup.topo.routing_table().num_ports();
    FaultState {
        plan: FaultPlan::none(),
        map: FaultMap {
            dead_tiles: Vec::new(),
            ports: vec![PortFault { failed: true, ..Default::default() }; num_ports],
            degraded_links: 0,
            flaky_links: 0,
            failed_links: num_ports / 2,
            healed_links: 0,
        },
        rank_tile: (0..setup.map.k).map(|r| setup.map.tile_of_rank(r)).collect(),
    }
}

#[test]
fn unreachable_destination_is_a_typed_error_not_a_panic() {
    let mut setup = DesignPoint::clos(256).mem_kb(128).k(255).build().unwrap();
    setup.fault = Some(severed_state(&setup));
    // Direct simulator probe: a cross-switch destination is a typed
    // FaultError (tile 100 sits on a different edge switch than the
    // client's tile 0 on the 256-tile Clos).
    let mut sim = NetworkSim::for_setup(&setup, 0);
    match sim.try_access(0, 100, 0) {
        Err(FaultError::Unreachable { from, to }) => assert_ne!(from, to),
        other => panic!("expected Unreachable, got {other:?}"),
    }
    // The scenario engine surfaces the same failure as a downcastable
    // error, never a panic.
    let err = run_scenario(&setup, 4, 100, 7, Workload::SharedUniform).unwrap_err();
    assert!(
        err.downcast_ref::<FaultError>().is_some(),
        "scenario error is not a FaultError: {err:#}"
    );
    assert!(err.to_string().contains("unreachable"), "{err}");
}
