//! Integration: the AOT XLA kernel and the native rust model agree
//! exactly across randomly-drawn design points (the property-test
//! version of `memclos selfcheck`).
//!
//! Skipped gracefully when `artifacts/` has not been built.

use memclos::emulation::{EmulationSetup, TopologyKind};
use memclos::runtime::{ArtifactSet, LatencyEngine};
use memclos::util::rng::Rng;

fn engine() -> Option<(ArtifactSet, LatencyEngine)> {
    let set = ArtifactSet::new().ok()?;
    if !set.available("latency_batch_4096") {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    let engine = LatencyEngine::load(&set, 4096).ok()?;
    Some((set, engine))
}

#[test]
fn xla_equals_native_over_random_design_points() {
    let Some((_set, engine)) = engine() else { return };
    let mut rng = Rng::new(0xFACE);
    let mut native = Vec::new();
    let mut addrs = vec![0i32; 4096];

    for case in 0..24 {
        let kind = if rng.chance(0.5) { TopologyKind::Clos } else { TopologyKind::Mesh };
        let tiles = match kind {
            TopologyKind::Clos => *rng.choose(&[64usize, 256, 512, 1024, 2048, 4096]),
            TopologyKind::Mesh => *rng.choose(&[64usize, 256, 1024, 4096]),
        };
        let mem = *rng.choose(&[64u32, 128, 256, 512]);
        let k = 1 + rng.below((tiles - 1) as u64) as usize;
        let setup = EmulationSetup::default_tech(kind, tiles, mem, k)
            .unwrap_or_else(|e| panic!("case {case}: setup {kind:?}/{tiles}/{mem}/{k}: {e}"));
        let params = setup.kernel_params();
        rng.fill_addresses(setup.map.space_words(), &mut addrs);

        let (xla, xla_mean) = engine.run(&addrs, &params).expect("xla run");
        setup.native_batch(&addrs, &mut native);

        for i in 0..addrs.len() {
            assert_eq!(
                xla[i], native[i],
                "case {case} ({kind:?} tiles={tiles} mem={mem} k={k}) addr {}: xla {} native {}",
                addrs[i], xla[i], native[i]
            );
        }
        let native_mean = native.iter().map(|&x| x as f64).sum::<f64>() / native.len() as f64;
        assert!(
            (xla_mean as f64 - native_mean).abs() < 1e-3,
            "case {case}: mean mismatch {xla_mean} vs {native_mean}"
        );
    }
}

#[test]
fn xla_mean_matches_exact_expectation() {
    let Some((set, _)) = engine() else { return };
    let engine = LatencyEngine::load(&set, 65_536).expect("65k artifact");
    let setup = EmulationSetup::default_tech(TopologyKind::Clos, 1024, 128, 1023).unwrap();
    let params = setup.kernel_params();
    let exact = setup.expected_latency();

    let mut rng = Rng::new(3);
    let mut addrs = vec![0i32; 65_536];
    let mut sum = 0.0;
    for _ in 0..4 {
        rng.fill_addresses(setup.map.space_words(), &mut addrs);
        let (_, mean) = engine.run(&addrs, &params).unwrap();
        sum += mean as f64;
    }
    let mc = sum / 4.0;
    assert!(
        (mc - exact).abs() / exact < 0.005,
        "MC {mc} vs exact {exact} (262k samples should be within 0.5%)"
    );
}
