//! Snapshot/resume bit-exactness, end to end: pausing a run at a
//! random cycle checkpoint, serialising the machine through the binary
//! snapshot format, rebuilding the memory from the recorded identity,
//! and resuming must reproduce the uninterrupted run exactly — same
//! `RunStats`, same register file, same error string on failure — on
//! every execution tier (legacy, fast, and — where the host supports
//! it — the baseline JIT) and both memory backends. Cross-tier
//! migration is a property too: a checkpoint exported from any tier
//! converts ([`convert_tier`]) and resumes under any other tier with
//! identical results, while an *unconverted* wrong-tier tag keeps
//! failing with the typed [`SnapshotError::WrongTier`].

use memclos::cc::{compile, corpus, Backend};
use memclos::cli::driver;
use memclos::emulation::{EmulationSetup, SequentialMachine, TopologyKind};
use memclos::isa::decode::{predecode, DecodedProgram};
use memclos::isa::interp::{
    DirectMemory, EmulatedChannelMemory, MachineState, MemorySystem,
};
use memclos::isa::jit;
use memclos::isa::snapshot::{
    convert_tier, program_fingerprint, rebuild_memory, run_fast_slice, run_jit_slice,
    run_legacy_slice, BackendSnap, SliceRun, Snapshot, SnapshotError, Tier,
};
use memclos::isa::Inst;
use memclos::util::rng::Rng;

const LOCAL_WORDS: usize = 1 << 14;
const DIRECT_SPACE: u64 = 1 << 20;
const MAX_STEPS: u64 = 50_000_000;

#[derive(Clone, Copy, PartialEq)]
enum Mem {
    Direct,
    Emulated,
}

fn point() -> EmulationSetup {
    EmulationSetup::default_tech(TopologyKind::Clos, 64, 64, 15).unwrap()
}

/// A blank start-of-program state (what `import_state` sizes the local
/// memory from).
fn blank() -> MachineState {
    MachineState { local: vec![0i64; LOCAL_WORDS], ..MachineState::default() }
}

enum Backing {
    Direct(DirectMemory),
    Emulated(EmulatedChannelMemory),
}

impl Backing {
    fn new(mem: Mem) -> Self {
        match mem {
            Mem::Direct => Backing::Direct(DirectMemory::new(
                SequentialMachine::paper_figures(false),
                DIRECT_SPACE,
            )),
            Mem::Emulated => Backing::Emulated(EmulatedChannelMemory::new(point())),
        }
    }

    fn as_dyn(&mut self) -> &mut dyn MemorySystem {
        match self {
            Backing::Direct(m) => m,
            Backing::Emulated(m) => m,
        }
    }

    /// Capture the backend identity + sparse pages for a snapshot.
    fn capture(&self) -> (BackendSnap, u64, Vec<(u64, Box<[i64]>)>) {
        match self {
            Backing::Direct(m) => {
                (BackendSnap::of_direct(m), DIRECT_SPACE, Snapshot::pages_of(m.store()))
            }
            Backing::Emulated(m) => (
                BackendSnap::of_emulated(m),
                m.setup().map.space_words(),
                Snapshot::pages_of(m.store()),
            ),
        }
    }
}

/// Every tier this host can run (the JIT registers itself only where
/// [`jit::available`] holds — elsewhere the lattice is legacy/fast).
fn available_tiers() -> Vec<Tier> {
    let mut tiers = vec![Tier::Legacy, Tier::Fast];
    if jit::available() {
        tiers.push(Tier::Jit);
    }
    tiers
}

fn run_slice(
    tier: Tier,
    code: &[Inst],
    decoded: &DecodedProgram,
    mem: &mut dyn MemorySystem,
    state: &MachineState,
    limit: Option<u64>,
) -> SliceRun {
    match tier {
        Tier::Fast => run_fast_slice(decoded, mem, state, MAX_STEPS, limit),
        Tier::Jit => {
            let native = jit::compile(decoded).expect("jit tier only runs where available");
            run_jit_slice(&native, mem, state, MAX_STEPS, limit)
        }
        Tier::Legacy => run_legacy_slice(code, mem, state, MAX_STEPS, limit),
    }
}

/// Pause `code` at `checkpoint` cycles, push the machine through the
/// full serialise → parse → rebuild path, resume, and return the final
/// [`SliceRun`]. Panics if any stage of the format round trip fails.
fn resume_via_snapshot(
    tier: Tier,
    mem_kind: Mem,
    name: &str,
    code: &[Inst],
    decoded: &DecodedProgram,
    checkpoint: u64,
) -> SliceRun {
    let mem_label = match mem_kind {
        Mem::Direct => "direct",
        Mem::Emulated => "emulated",
    };
    let ctx = format!("{name}/{}/{mem_label}-at-{checkpoint}", tier.label());
    let mut backing = Backing::new(mem_kind);
    let part1 = run_slice(tier, code, decoded, backing.as_dyn(), &blank(), Some(checkpoint));
    match part1.outcome {
        Ok(false) => {} // paused at the budget: the interesting path
        Ok(true) => return part1, // the last op crossed the finish line first
        Err(e) => panic!("{ctx}: first slice errored before the checkpoint: {e}"),
    }
    let (backend, space_words, pages) = backing.capture();
    let snap = Snapshot {
        tier,
        backend,
        space_words,
        max_steps: MAX_STEPS,
        program: name.to_string(),
        program_fnv: program_fingerprint(code),
        state: part1.state,
        pages,
    };
    let reparsed = Snapshot::from_bytes(&snap.to_bytes())
        .unwrap_or_else(|e| panic!("{ctx}: round trip rejected: {e}"));
    reparsed.check_tier(tier).unwrap();
    reparsed.check_program(code).unwrap();
    let mut rebuilt =
        rebuild_memory(&reparsed).unwrap_or_else(|e| panic!("{ctx}: rebuild failed: {e}"));
    run_slice(tier, code, decoded, rebuilt.as_dyn(), &reparsed.state, None)
}

#[test]
fn random_checkpoints_resume_bit_identically_across_tiers_and_backends() {
    let programs = ["sum_squares", "sieve", "fib_memo"];
    let mut r = Rng::new(0x5EED_0001);
    for name in programs {
        let prog = corpus::all().into_iter().find(|p| p.name == name).unwrap();
        for (mem_kind, cc_backend) in
            [(Mem::Direct, Backend::Direct), (Mem::Emulated, Backend::Emulated)]
        {
            let code = compile(prog.source, cc_backend).unwrap().code;
            let decoded = predecode(&code).unwrap();
            for tier in available_tiers() {
                // Uninterrupted reference run.
                let mut backing = Backing::new(mem_kind);
                let reference =
                    run_slice(tier, &code, &decoded, backing.as_dyn(), &blank(), None);
                assert_eq!(reference.outcome, Ok(true), "{name}: reference must halt");
                if let Some(want) = prog.expected {
                    assert_eq!(reference.state.regs[0], want, "{name}: wrong result");
                }
                let total = reference.state.stats.cycles;
                assert!(total > 2, "{name}: too short to checkpoint");
                // Property: ANY cycle boundary is a valid migration
                // point. Sample random checkpoints across the run.
                for _ in 0..4 {
                    let checkpoint = 1 + r.below(total - 1);
                    let resumed = resume_via_snapshot(
                        tier, mem_kind, name, &code, &decoded, checkpoint,
                    );
                    assert_eq!(
                        resumed.outcome,
                        Ok(true),
                        "{name}/{}/at-{checkpoint}: resume did not halt",
                        tier.label()
                    );
                    assert_eq!(
                        resumed.state.stats, reference.state.stats,
                        "{name}/{}/at-{checkpoint}: stats diverge",
                        tier.label()
                    );
                    assert_eq!(
                        resumed.state.regs, reference.state.regs,
                        "{name}/{}/at-{checkpoint}: registers diverge",
                        tier.label()
                    );
                }
            }
        }
    }
}

#[test]
fn cross_tier_checkpoints_migrate_bit_identically() {
    // The migration property: a checkpoint exported from tier A,
    // serialised through the binary format, *converted* with
    // `convert_tier`, and resumed under tier B finishes with the
    // identical RunStats and register file. Fast ↔ jit share the
    // decoded cursor space (a pure retag, must never refuse); legacy
    // checkpoints can land inside a fused channel sequence or
    // mid-transaction, where conversion refuses with a typed,
    // field-named error instead of guessing.
    let programs = ["sum_squares", "sieve"];
    let mut r = Rng::new(0x5EED_0003);
    let tiers = available_tiers();
    for name in programs {
        let prog = corpus::all().into_iter().find(|p| p.name == name).unwrap();
        for (mem_kind, cc_backend) in
            [(Mem::Direct, Backend::Direct), (Mem::Emulated, Backend::Emulated)]
        {
            let code = compile(prog.source, cc_backend).unwrap().code;
            let decoded = predecode(&code).unwrap();
            // All tiers are bit-identical, so one reference serves.
            let mut backing = Backing::new(mem_kind);
            let reference = run_slice(Tier::Fast, &code, &decoded, backing.as_dyn(), &blank(), None);
            assert_eq!(reference.outcome, Ok(true), "{name}: reference must halt");
            let total = reference.state.stats.cycles;
            for &from in &tiers {
                for &to in &tiers {
                    if from == to {
                        continue;
                    }
                    let ctx = || format!("{name}/{}->{}", from.label(), to.label());
                    // Legacy checkpoints on the emulated backend often
                    // land mid-transaction or inside a fused channel
                    // sequence, where conversion (correctly) refuses —
                    // give those pairs more draws to find convertible
                    // pause points.
                    let attempts =
                        if from == Tier::Legacy || to == Tier::Legacy { 12 } else { 4 };
                    let mut migrated = 0usize;
                    for _ in 0..attempts {
                        if migrated >= 2 {
                            break;
                        }
                        let checkpoint = 1 + r.below(total - 1);
                        let mut b = Backing::new(mem_kind);
                        let part1 = run_slice(
                            from, &code, &decoded, b.as_dyn(), &blank(), Some(checkpoint),
                        );
                        match &part1.outcome {
                            Ok(false) => {} // paused at the budget
                            Ok(true) => continue, // the last op crossed the finish line
                            Err(e) => {
                                panic!("{}: first slice errored before the checkpoint: {e}", ctx())
                            }
                        }
                        let (backend, space_words, pages) = b.capture();
                        let snap = Snapshot {
                            tier: from,
                            backend,
                            space_words,
                            max_steps: MAX_STEPS,
                            program: name.to_string(),
                            program_fnv: program_fingerprint(&code),
                            state: part1.state,
                            pages,
                        };
                        let reparsed = Snapshot::from_bytes(&snap.to_bytes()).unwrap();
                        // The UNCONVERTED tag still refuses the other
                        // tier, typed — conversion is explicit, never
                        // implied by the importer.
                        match reparsed.check_tier(to) {
                            Err(SnapshotError::WrongTier { found, want }) => {
                                assert_eq!(found, from.label(), "{}", ctx());
                                assert_eq!(want, to.label(), "{}", ctx());
                            }
                            other => panic!("{}: check_tier must refuse: {other:?}", ctx()),
                        }
                        let converted = match convert_tier(&reparsed, to, &decoded) {
                            Ok(c) => c,
                            Err(SnapshotError::Field { field, .. }) => {
                                assert!(
                                    from == Tier::Legacy || to == Tier::Legacy,
                                    "{}: decoded-pc tiers must always retag, refused on `{field}`",
                                    ctx()
                                );
                                continue;
                            }
                            Err(e) => panic!("{}: unexpected conversion error: {e}", ctx()),
                        };
                        converted.check_tier(to).unwrap();
                        let mut rebuilt = rebuild_memory(&converted).unwrap();
                        let resumed = run_slice(
                            to, &code, &decoded, rebuilt.as_dyn(), &converted.state, None,
                        );
                        assert_eq!(resumed.outcome, Ok(true), "{}: resume did not halt", ctx());
                        assert_eq!(
                            resumed.state.stats, reference.state.stats,
                            "{}: stats diverge after migration",
                            ctx()
                        );
                        assert_eq!(
                            resumed.state.regs, reference.state.regs,
                            "{}: registers diverge after migration",
                            ctx()
                        );
                        migrated += 1;
                    }
                    assert!(migrated > 0, "{}: no checkpoint migrated", ctx());
                }
            }
        }
    }
}

#[test]
fn resuming_a_failing_run_reproduces_the_error_string_exactly() {
    // A program that trips the step limit: pausing and resuming must
    // reproduce the uninterrupted error string byte for byte (the step
    // limit is recorded in the snapshot for exactly this reason).
    let src = "global x;\nfn main() { var i = 0; while (0 < 1) { x = x + 1; i = i + 1; } return i; }";
    let max_steps = 10_000u64;
    let mut r = Rng::new(0x5EED_0002);
    for (mem_kind, cc_backend) in
        [(Mem::Direct, Backend::Direct), (Mem::Emulated, Backend::Emulated)]
    {
        let code = compile(src, cc_backend).unwrap().code;
        let decoded = predecode(&code).unwrap();
        for tier in available_tiers() {
            let slice = |mem: &mut dyn MemorySystem,
                         state: &MachineState,
                         limit: Option<u64>|
             -> SliceRun {
                match tier {
                    Tier::Fast => run_fast_slice(&decoded, mem, state, max_steps, limit),
                    Tier::Jit => {
                        let native = jit::compile(&decoded).unwrap();
                        run_jit_slice(&native, mem, state, max_steps, limit)
                    }
                    Tier::Legacy => run_legacy_slice(&code, mem, state, max_steps, limit),
                }
            };
            let mut backing = Backing::new(mem_kind);
            let reference = slice(backing.as_dyn(), &blank(), None);
            let want = reference.outcome.clone().expect_err("must hit the step limit");
            assert_eq!(want, format!("step limit exceeded ({max_steps})"));

            // Pause somewhere before the limit, snapshot, resume.
            let checkpoint = 1 + r.below(max_steps / 2);
            let mut b2 = Backing::new(mem_kind);
            let part1 = slice(b2.as_dyn(), &blank(), Some(checkpoint));
            assert_eq!(part1.outcome, Ok(false), "must pause before the step limit");
            let (backend, space_words, pages) = b2.capture();
            let snap = Snapshot {
                tier,
                backend,
                space_words,
                max_steps,
                program: "steplimit".to_string(),
                program_fnv: program_fingerprint(&code),
                state: part1.state,
                pages,
            };
            let reparsed = Snapshot::from_bytes(&snap.to_bytes()).unwrap();
            let mut rebuilt = rebuild_memory(&reparsed).unwrap();
            let resumed = slice(rebuilt.as_dyn(), &reparsed.state, None);
            let got = resumed.outcome.expect_err("resumed run must fail the same way");
            assert_eq!(got, want, "{}: error strings must be bit-identical", tier.label());
        }
    }
}

#[test]
fn cli_save_then_resume_with_verify_round_trips() {
    // The user-facing path: `memclos snapshot save` writes a blob,
    // `memclos snapshot resume --verify` rebuilds, replays from zero,
    // and cross-checks the resumed run against the full re-execution.
    let dir = std::env::temp_dir().join("memclos-snapshot-resume-test");
    std::fs::create_dir_all(&dir).unwrap();
    let out = dir.join("sum_squares.snap");
    let run = |line: String| {
        driver::run(line.split_whitespace().map(str::to_string).collect())
            .unwrap_or_else(|e| panic!("`{line}` failed: {e:#}"))
    };
    run(format!(
        "snapshot save --program sum_squares --at 500 --tiles 64 --k 15 --mem 64 --out {}",
        out.display()
    ));
    assert!(out.exists(), "save must write the blob");
    run(format!("snapshot resume --in {} --verify", out.display()));
    // Legacy tier through the same CLI.
    let out2 = dir.join("sieve-legacy.snap");
    run(format!(
        "snapshot save --program sieve --at 400 --legacy --tiles 64 --k 15 --mem 64 --out {}",
        out2.display()
    ));
    run(format!("snapshot resume --in {} --verify", out2.display()));
    // A direct-backend snapshot migrates too.
    let out3 = dir.join("fib-direct.snap");
    run(format!(
        "snapshot save --program fib_memo --at 200 --backend direct --out {}",
        out3.display()
    ));
    run(format!("snapshot resume --in {} --verify", out3.display()));
    std::fs::remove_dir_all(&dir).ok();
}
