#!/usr/bin/env bash
# Quick-smoke run of the perf-trajectory benches; writes the
# machine-readable results to the repo root so successive PRs can diff
# throughput:
#
#   BENCH_hotpath.json    — the emulated-memory access hot path
#   BENCH_interp.json     — decoded-vs-legacy whole-program interpretation
#   BENCH_contention.json — trace generation + DES contention replay
#   BENCH_faults.json     — healthy-vs-faulted DES replay + fault build cost
#
# Schema (all files): {"bench": <group>,
#          "results": [{"name", "median_ns", "addrs_per_s"}]}
#
# Usage: rust/scripts/bench_hotpath.sh [--full]
#   --full   use the full measurement budget instead of the smoke one

set -euo pipefail

RUST_DIR="$(cd "$(dirname "$0")/.." && pwd)"
REPO_ROOT="$(cd "$RUST_DIR/.." && pwd)"
OUT="$REPO_ROOT/BENCH_hotpath.json"
INTERP_OUT="$REPO_ROOT/BENCH_interp.json"
CONT_OUT="$REPO_ROOT/BENCH_contention.json"
FAULTS_OUT="$REPO_ROOT/BENCH_faults.json"

if [[ "${1:-}" != "--full" ]]; then
    export MEMCLOS_BENCH_QUICK=1
fi

cd "$RUST_DIR"

# Prefer the bench binaries (hotpath covers the XLA paths too); fall
# back to the CLI subcommands, which measure the native/DES/interpreter
# paths only.
if cargo bench --bench hotpath -- --json "$OUT"; then
    :
else
    echo "(cargo bench failed; falling back to the CLI bench-hotpath)" >&2
    cargo run --release --bin memclos -- bench-hotpath --out "$OUT"
fi

echo "perf trajectory written to $OUT"

if cargo bench --bench interp -- --json "$INTERP_OUT"; then
    :
else
    echo "(cargo bench interp failed; falling back to the CLI bench-interp)" >&2
    cargo run --release --bin memclos -- bench-interp --out "$INTERP_OUT"
fi

echo "interp trajectory written to $INTERP_OUT"

if cargo bench --bench contention -- --json "$CONT_OUT"; then
    :
else
    echo "(cargo bench contention failed; falling back to the CLI contention --json)" >&2
    cargo run --release --bin memclos -- contention --clients 8 --json > "$CONT_OUT"
fi

echo "contention trajectory written to $CONT_OUT"

if cargo bench --bench faults -- --json "$FAULTS_OUT"; then
    :
else
    echo "(cargo bench faults failed; falling back to the CLI faults --json)" >&2
    cargo run --release --bin memclos -- faults --json > "$FAULTS_OUT"
fi

echo "faults trajectory written to $FAULTS_OUT"
