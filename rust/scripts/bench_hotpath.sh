#!/usr/bin/env bash
# Quick-smoke run of the perf-trajectory benches; writes the
# machine-readable results to the repo root so successive PRs can diff
# throughput:
#
#   BENCH_hotpath.json    — the emulated-memory access hot path
#   BENCH_interp.json     — decoded-vs-legacy whole-program interpretation
#   BENCH_jit.json        — the baseline JIT tier vs legacy on the same
#                           corpus (written empty, with a notice, on
#                           hosts the JIT does not target)
#   BENCH_contention.json — trace generation + DES contention replay
#   BENCH_faults.json     — healthy-vs-faulted DES replay + fault build cost
#   BENCH_serve.json      — serve layer: frame codec, request parse,
#                           Service::handle hot/cold, plus a live
#                           serve/loadgen smoke over real TCP
#   BENCH_fuzz.json       — fuzz-case generation, the differential
#                           harness, and the snapshot round trip
#   BENCH_scale.json      — topology + computed-router build time and
#                           uncontended DES throughput at 1K/64K/1M
#                           tiles, plus the O(V) router memory ceiling
#
# Schema (all files): {"bench": <group>,
#          "results": [{"name", "median_ns", "addrs_per_s"}]}
#
# Usage: rust/scripts/bench_hotpath.sh [--full]
#   --full   use the full measurement budget instead of the smoke one

set -euo pipefail

RUST_DIR="$(cd "$(dirname "$0")/.." && pwd)"
REPO_ROOT="$(cd "$RUST_DIR/.." && pwd)"
OUT="$REPO_ROOT/BENCH_hotpath.json"
INTERP_OUT="$REPO_ROOT/BENCH_interp.json"
JIT_OUT="$REPO_ROOT/BENCH_jit.json"
CONT_OUT="$REPO_ROOT/BENCH_contention.json"
FAULTS_OUT="$REPO_ROOT/BENCH_faults.json"
SERVE_OUT="$REPO_ROOT/BENCH_serve.json"
FUZZ_OUT="$REPO_ROOT/BENCH_fuzz.json"
SCALE_OUT="$REPO_ROOT/BENCH_scale.json"

if [[ "${1:-}" != "--full" ]]; then
    export MEMCLOS_BENCH_QUICK=1
fi

cd "$RUST_DIR"

# Prefer the bench binaries (hotpath covers the XLA paths too); fall
# back to the CLI subcommands, which measure the native/DES/interpreter
# paths only.
if cargo bench --bench hotpath -- --json "$OUT"; then
    :
else
    echo "(cargo bench failed; falling back to the CLI bench-hotpath)" >&2
    cargo run --release --bin memclos -- bench-hotpath --out "$OUT"
fi

echo "perf trajectory written to $OUT"

# The interp bench also runs the third tier and writes BENCH_jit.json
# (empty, with a notice, on hosts the JIT does not target); the CLI
# fallback covers the jit group with its own subcommand.
if cargo bench --bench interp -- --json "$INTERP_OUT" --json-jit "$JIT_OUT"; then
    :
else
    echo "(cargo bench interp failed; falling back to the CLI bench-interp + bench-jit)" >&2
    cargo run --release --bin memclos -- bench-interp --out "$INTERP_OUT"
    cargo run --release --bin memclos -- bench-jit --out "$JIT_OUT"
fi

echo "interp trajectory written to $INTERP_OUT"
echo "jit trajectory written to $JIT_OUT"

if cargo bench --bench contention -- --json "$CONT_OUT"; then
    :
else
    echo "(cargo bench contention failed; falling back to the CLI contention --json)" >&2
    cargo run --release --bin memclos -- contention --clients 8 --json > "$CONT_OUT"
fi

echo "contention trajectory written to $CONT_OUT"

if cargo bench --bench faults -- --json "$FAULTS_OUT"; then
    :
else
    echo "(cargo bench faults failed; falling back to the CLI faults --json)" >&2
    cargo run --release --bin memclos -- faults --json > "$FAULTS_OUT"
fi

echo "faults trajectory written to $FAULTS_OUT"

if cargo bench --bench fuzz -- --json "$FUZZ_OUT"; then
    echo "fuzz trajectory written to $FUZZ_OUT"
else
    echo "(cargo bench fuzz failed; running the CLI fuzz smoke instead — no $FUZZ_OUT)" >&2
    cargo run --release --bin memclos -- fuzz --cases 256 --seed 0 --no-shrink
fi

# Scale trajectory: build time + DES throughput at 1K/64K/1M tiles and
# the hard O(V) router-memory ceiling (the bench fails if an O(n^2)
# structure ever returns to the healthy routing path). The fallback
# smoke renders the scale figure, which exercises the same machinery
# but writes no JSON.
if cargo bench --bench scale -- --json "$SCALE_OUT"; then
    echo "scale trajectory written to $SCALE_OUT"
else
    echo "(cargo bench scale failed; running the CLI figure scale smoke instead — no $SCALE_OUT)" >&2
    cargo run --release --bin memclos -- figure scale
fi

# Serve-layer microbenches (frame codec, request parse, Service::handle
# hot/cold). The live smoke below overwrites SERVE_OUT with the fuller
# closed-loop report when it succeeds; the microbench file stands in
# when it does not.
if cargo bench --bench serve -- --json "$SERVE_OUT"; then
    :
else
    echo "(cargo bench serve failed; the loadgen smoke below writes $SERVE_OUT instead)" >&2
fi

# Live serve/loadgen smoke: a real server on an ephemeral port, the
# closed-loop load generator against it over TCP, then a graceful wire
# drain. Falls back to the in-process self-hosted pair if the server
# never publishes its port.
PORT_FILE="$(mktemp)"
cargo run --release --bin memclos -- serve --addr 127.0.0.1:0 --port-file "$PORT_FILE" --mode exact &
SERVE_PID=$!
PORT=""
for _ in $(seq 1 100); do
    if [[ -s "$PORT_FILE" ]]; then
        PORT="$(tr -d '[:space:]' < "$PORT_FILE")"
        break
    fi
    sleep 0.1
done
if [[ -n "$PORT" ]]; then
    cargo run --release --bin memclos -- loadgen --addr "127.0.0.1:$PORT" \
        --clients 4 --requests 32 --shutdown --out "$SERVE_OUT"
    wait "$SERVE_PID"
else
    echo "(serve never published its port; falling back to loadgen --self-host)" >&2
    kill "$SERVE_PID" 2>/dev/null || true
    wait "$SERVE_PID" 2>/dev/null || true
    cargo run --release --bin memclos -- loadgen --self-host --mode exact \
        --clients 4 --requests 32 --out "$SERVE_OUT"
fi
rm -f "$PORT_FILE"

echo "serve trajectory written to $SERVE_OUT"
