#!/usr/bin/env bash
# Quick-smoke run of the access-hot-path bench; writes the
# machine-readable perf trajectory to BENCH_hotpath.json at the repo
# root so successive PRs can diff throughput.
#
# Schema: {"bench": "hotpath",
#          "results": [{"name", "median_ns", "addrs_per_s"}]}
#
# Usage: rust/scripts/bench_hotpath.sh [--full]
#   --full   use the full measurement budget instead of the smoke one

set -euo pipefail

RUST_DIR="$(cd "$(dirname "$0")/.." && pwd)"
REPO_ROOT="$(cd "$RUST_DIR/.." && pwd)"
OUT="$REPO_ROOT/BENCH_hotpath.json"

if [[ "${1:-}" != "--full" ]]; then
    export MEMCLOS_BENCH_QUICK=1
fi

cd "$RUST_DIR"

# Prefer the bench binary (covers the XLA paths too); fall back to the
# CLI subcommand, which measures the native/DES/interpreter paths only.
if cargo bench --bench hotpath -- --json "$OUT"; then
    :
else
    echo "(cargo bench failed; falling back to the CLI bench-hotpath)" >&2
    cargo run --release --bin memclos -- bench-hotpath --out "$OUT"
fi

echo "perf trajectory written to $OUT"
