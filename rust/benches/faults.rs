//! Bench: the fault subsystem — DES replay throughput (accesses/s) of
//! the 1,024-tile Clos point healthy vs under a 5 % fault plan (dead
//! tiles + degraded/flaky links + failed ports), for the uniform and
//! zipf patterns, plus the cost of materialising a faulted design
//! point.
//!
//! Writes the machine-readable perf trajectory to `BENCH_faults.json`
//! (override the path with `--json PATH`; same schema family as
//! `BENCH_hotpath.json`, emitted by `rust/scripts/bench_hotpath.sh`,
//! uploaded by CI) and then runs the oracle smoke: the faulted replay
//! is seed-deterministic (two runs bit-equal), and the empty-plan
//! setup reproduces the legacy healthy `run_contention` summary bit
//! for bit.
//!
//! Quick smoke mode: set `MEMCLOS_BENCH_QUICK=1` (what
//! `rust/scripts/bench_hotpath.sh` does).

use std::path::PathBuf;

use memclos::api::DesignPoint;
use memclos::fault::FaultPlan;
use memclos::sim::contention::{run_scenario, Workload};
use memclos::sim::network::run_contention;
use memclos::util::bench::{black_box, Bench};
use memclos::workload::{Trace, TracePattern};

const CLIENTS: usize = 16;
const ACCESSES: usize = 200;
const FAULT_FRAC: f64 = 0.05;
const FAULT_SEED: u64 = 0xFA17;

fn json_path() -> PathBuf {
    let args: Vec<String> = std::env::args().collect();
    for w in args.windows(2) {
        if w[0] == "--json" {
            return PathBuf::from(&w[1]);
        }
    }
    PathBuf::from("BENCH_faults.json")
}

fn main() {
    // k = 896 leaves dead-tile slack for the 5 % plan (full emulation
    // would reject any dead tile under the capacity-degradation rule).
    let healthy = DesignPoint::clos(1024).mem_kb(128).k(896).build().unwrap();
    let faulted = DesignPoint::clos(1024)
        .mem_kb(128)
        .k(896)
        .faults(FaultPlan::fraction(FAULT_FRAC, FAULT_SEED))
        .build()
        .unwrap();
    assert!(faulted.fault.is_some(), "5% plan must materialise");

    let space = healthy.map.space_words();
    let block = 1u64 << healthy.map.log2_words_per_tile;

    let mut b = Bench::new("faults");

    // Materialisation cost: building the faulted point (topology +
    // fault sampling + heal rule + rank remap + LUT).
    b.iter("build-faulted", || {
        let s = DesignPoint::clos(1024)
            .mem_kb(128)
            .k(896)
            .faults(FaultPlan::fraction(FAULT_FRAC, FAULT_SEED))
            .build()
            .unwrap();
        black_box(s.rank_latencies().len())
    });

    // DES replay throughput, healthy vs faulted, per pattern. The same
    // traces replay on both setups so the delta is the fault tax.
    for &pat in &[TracePattern::Uniform, TracePattern::Zipf { theta: 1.2 }] {
        let traces: Vec<Trace> = (0..CLIENTS)
            .map(|c| pat.generate(space, block, ACCESSES, 0x7EA5 + c as u64))
            .collect();
        b.iter_items(
            &format!("replay-healthy-{}", pat.label()),
            (CLIENTS * ACCESSES) as u64,
            || {
                let r = run_scenario(&healthy, CLIENTS, ACCESSES, 7, Workload::Traces(&traces))
                    .expect("healthy replay");
                black_box(r.latency.count())
            },
        );
        b.iter_items(
            &format!("replay-faulted-{}", pat.label()),
            (CLIENTS * ACCESSES) as u64,
            || {
                let r = run_scenario(&faulted, CLIENTS, ACCESSES, 7, Workload::Traces(&traces))
                    .expect("sampled plans never sever the network");
                black_box(r.latency.count())
            },
        );
    }

    b.report();
    println!("\nthroughput (items/s):");
    for m in b.results() {
        if m.items > 0 {
            println!("  {:<28} {:>14.0}", m.name, m.throughput());
        }
    }

    // Perf trajectory lands on disk before the assertions run, so a
    // regression still records its numbers.
    let path = json_path();
    b.write_json(&path).expect("write bench json");
    println!("wrote {}", path.display());

    // Oracle smoke 1: the faulted replay is seed-deterministic.
    let a = run_scenario(&faulted, CLIENTS, ACCESSES, 7, Workload::SharedUniform)
        .expect("faulted replay");
    let c = run_scenario(&faulted, CLIENTS, ACCESSES, 7, Workload::SharedUniform)
        .expect("faulted replay");
    assert_eq!(a.latency.mean().to_bits(), c.latency.mean().to_bits(), "faulted replay drifted");
    assert_eq!(a.retries, c.retries);
    assert_eq!(a.timeouts, c.timeouts);
    println!("determinism smoke OK (faulted replay bit-stable, {} retries)", a.retries);

    // Oracle smoke 2: the empty-plan path IS the legacy healthy
    // experiment, bit for bit.
    let empty = DesignPoint::clos(1024)
        .mem_kb(128)
        .k(896)
        .faults(FaultPlan::none())
        .build()
        .unwrap();
    assert!(empty.fault.is_none(), "empty plan must not materialise");
    let new = run_scenario(&empty, CLIENTS, ACCESSES, 7, Workload::SharedUniform)
        .expect("healthy replay");
    let old = run_contention(&healthy, CLIENTS, ACCESSES, 7);
    assert_eq!(
        new.latency.mean().to_bits(),
        old.latency.mean().to_bits(),
        "empty-plan scenario diverged from run_contention"
    );
    assert_eq!(new.latency.count(), old.latency.count());
    assert_eq!(new.inflation.to_bits(), old.inflation.to_bits());
    assert_eq!(new.retries + new.timeouts, 0);
    println!("oracle smoke OK (empty-plan replay == legacy run_contention bitwise)");
}
