//! Bench: the contention lab — trace generation throughput per pattern
//! and DES replay throughput (accesses/s) for a 16-client crowd on the
//! 1,024-tile full-emulation Clos point, against the legacy uniform
//! loop as the baseline.
//!
//! Writes the machine-readable perf trajectory to
//! `BENCH_contention.json` (override the path with `--json PATH`; same
//! schema family as `BENCH_hotpath.json`, emitted by
//! `rust/scripts/bench_hotpath.sh`, uploaded by CI) and then runs the
//! oracle smoke: the engine's shared-uniform scenario must reproduce
//! the legacy `run_contention` summary bit for bit.
//!
//! Quick smoke mode: set `MEMCLOS_BENCH_QUICK=1` (what
//! `rust/scripts/bench_hotpath.sh` does).

use std::path::PathBuf;

use memclos::api::DesignPoint;
use memclos::sim::contention::{run_scenario, Workload};
use memclos::sim::network::run_contention;
use memclos::util::bench::{black_box, Bench};
use memclos::workload::Trace;

const CLIENTS: usize = 16;
const ACCESSES: usize = 200;
const GEN_LEN: usize = 4096;

fn json_path() -> PathBuf {
    let args: Vec<String> = std::env::args().collect();
    for w in args.windows(2) {
        if w[0] == "--json" {
            return PathBuf::from(&w[1]);
        }
    }
    PathBuf::from("BENCH_contention.json")
}

fn main() {
    let setup = DesignPoint::clos(1024).mem_kb(128).k(1023).build().unwrap();
    let space = setup.map.space_words();
    let block = 1u64 << setup.map.log2_words_per_tile;
    // ONE catalogue definition for the whole crate: the figure's.
    let patterns = memclos::figures::contention::patterns(block);

    let mut b = Bench::new("contention");

    // Trace generation throughput (addresses/s) per pattern.
    for &pat in &patterns {
        b.iter_items(&format!("gen-{}", pat.label()), GEN_LEN as u64, || {
            black_box(pat.generate(space, block, GEN_LEN, 7).addrs.len())
        });
    }

    // DES replay throughput (issued accesses/s) per pattern, plus the
    // two uniform implementations side by side.
    for &pat in &patterns {
        let traces: Vec<Trace> = (0..CLIENTS)
            .map(|c| pat.generate(space, block, ACCESSES, 0x7EA5 + c as u64))
            .collect();
        b.iter_items(
            &format!("replay-{}", pat.label()),
            (CLIENTS * ACCESSES) as u64,
            || {
                let r = run_scenario(&setup, CLIENTS, ACCESSES, 7, Workload::Traces(&traces))
                    .expect("healthy replay");
                black_box(r.latency.count())
            },
        );
    }
    b.iter_items("replay-shared-uniform", (CLIENTS * ACCESSES) as u64, || {
        let r = run_scenario(&setup, CLIENTS, ACCESSES, 7, Workload::SharedUniform)
            .expect("healthy replay");
        black_box(r.latency.count())
    });
    b.iter_items("legacy-uniform", (CLIENTS * ACCESSES) as u64, || {
        let r = run_contention(&setup, CLIENTS, ACCESSES, 7);
        black_box(r.latency.count())
    });

    b.report();
    println!("\nthroughput (items/s):");
    for m in b.results() {
        if m.items > 0 {
            println!("  {:<24} {:>14.0}", m.name, m.throughput());
        }
    }

    // Perf trajectory lands on disk before the assertions run, so a
    // regression still records its numbers.
    let path = json_path();
    b.write_json(&path).expect("write bench json");
    println!("wrote {}", path.display());

    // Oracle smoke: the engine's uniform path IS the legacy experiment.
    let new = run_scenario(&setup, CLIENTS, ACCESSES, 7, Workload::SharedUniform)
        .expect("healthy replay");
    let old = run_contention(&setup, CLIENTS, ACCESSES, 7);
    assert_eq!(
        new.latency.mean().to_bits(),
        old.latency.mean().to_bits(),
        "shared-uniform scenario diverged from run_contention"
    );
    assert_eq!(new.latency.count(), old.latency.count());
    assert_eq!(new.inflation.to_bits(), old.inflation.to_bits());
    println!("oracle smoke OK (engine uniform == legacy run_contention bitwise)");
}
