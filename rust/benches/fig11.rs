//! Bench: regenerate paper Fig 11 (slowdown vs instruction mix) and
//! time the sweep (uses the AOT mix-sweep artifact when available).

use memclos::figures::{fig11, FigOpts};
use memclos::util::bench::Bench;

fn main() {
    let opts = FigOpts::auto();
    let rows = fig11::generate(&opts).expect("fig11");
    println!("{}", fig11::render(&rows));

    let mut b = Bench::new("fig11");
    let exact = FigOpts::default();
    b.iter("generate-exact", || fig11::generate(&exact).unwrap());
    b.report();
}
