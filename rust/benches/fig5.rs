//! Bench: regenerate paper Fig 5 (chip area vs tiles) and time the
//! floorplan model.

use memclos::figures::fig5;
use memclos::tech::ChipTech;
use memclos::util::bench::Bench;

fn main() {
    let tech = ChipTech::default();
    let rows = fig5::generate(&tech).expect("fig5");
    println!("{}", fig5::render(&rows, &tech));

    let mut b = Bench::new("fig5");
    b.iter("generate", || fig5::generate(&tech).unwrap());
    b.report();
}
