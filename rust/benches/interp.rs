//! Bench: whole-program interpretation — the pre-decoded
//! direct-threaded loop ([`memclos::isa::decode::FastMachine`]) vs the
//! legacy enum-match loop ([`memclos::isa::interp::Machine`]) over the
//! full cc corpus on both memory systems, plus the decode-once cost —
//! and the third tier: the baseline JIT ([`memclos::isa::jit`]) over
//! the same corpus.
//!
//! Writes the machine-readable perf trajectory to `BENCH_interp.json`
//! and `BENCH_jit.json` (override with `--json PATH` / `--json-jit
//! PATH`; same schema family as `BENCH_hotpath.json`) and then
//! enforces the floors: decoded >= 5x legacy and jit >= 50x legacy on
//! the emulated corpus. Both JSON files land on disk *before* their
//! assertions run, so a regression still records its numbers. On
//! hosts the JIT does not target, `BENCH_jit.json` is written with an
//! empty result set and the jit floor is skipped with a notice — the
//! interp floors still apply everywhere.
//!
//! Quick smoke mode: set `MEMCLOS_BENCH_QUICK=1` (what
//! `rust/scripts/bench_hotpath.sh` does).

use std::path::PathBuf;

use memclos::figures::interp_bench;
use memclos::util::bench::Bench;

fn flag_path(flag: &str, default: &str) -> PathBuf {
    let args: Vec<String> = std::env::args().collect();
    for w in args.windows(2) {
        if w[0] == flag {
            return PathBuf::from(&w[1]);
        }
    }
    PathBuf::from(default)
}

fn main() {
    let w = interp_bench::workload().expect("corpus compiles + predecodes");
    println!(
        "corpus: {} programs, {} direct / {} emulated instructions per pass",
        w.corpus.programs.len(),
        w.direct_insts,
        w.emulated_insts
    );

    let b = interp_bench::measure(&w);
    b.report();
    println!("\n{}", interp_bench::render(&b));

    // Perf trajectory lands on disk before the assertions run, so a
    // regression still records its numbers.
    let path = flag_path("--json", "BENCH_interp.json");
    b.write_json(&path).expect("write bench json");
    println!("wrote {}", path.display());

    interp_bench::assert_interp(&b).expect("interpreter throughput floors");
    println!(
        "interp assertions OK (decoded {:.1}x legacy on the emulated corpus)",
        interp_bench::speedup(&b).unwrap()
    );

    // Third tier: the baseline JIT, same corpus, same design point.
    let jit_path = flag_path("--json-jit", "BENCH_jit.json");
    if memclos::isa::jit::available() {
        let jb = interp_bench::measure_jit(&w).expect("jit corpus compiles");
        jb.report();
        println!("\n{}", interp_bench::render_jit(&jb));
        jb.write_json(&jit_path).expect("write jit bench json");
        println!("wrote {}", jit_path.display());
        interp_bench::assert_jit(&jb).expect("jit throughput floors");
        println!(
            "jit assertions OK (jit {:.1}x legacy on the emulated corpus)",
            interp_bench::jit_speedup(&jb).unwrap()
        );
    } else {
        // Typed, explicit degradation: record an empty jit group so the
        // artifact family stays complete, and say why.
        Bench::new("jit").write_json(&jit_path).expect("write jit bench json");
        println!("wrote {} (empty: JIT tier unavailable on this host)", jit_path.display());
        println!(
            "skipping jit floor: {}",
            memclos::isa::JitUnsupported::host()
        );
    }
}
