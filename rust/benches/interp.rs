//! Bench: whole-program interpretation — the pre-decoded
//! direct-threaded loop ([`memclos::isa::decode::FastMachine`]) vs the
//! legacy enum-match loop ([`memclos::isa::interp::Machine`]) over the
//! full cc corpus on both memory systems, plus the decode-once cost.
//!
//! Writes the machine-readable perf trajectory to `BENCH_interp.json`
//! (override the path with `--json PATH`; same schema family as
//! `BENCH_hotpath.json`) and then enforces the floor: the decoded
//! interpreter must be >= 5x the legacy loop on the emulated corpus.
//!
//! Quick smoke mode: set `MEMCLOS_BENCH_QUICK=1` (what
//! `rust/scripts/bench_hotpath.sh` does).

use std::path::PathBuf;

use memclos::figures::interp_bench;

fn json_path() -> PathBuf {
    let args: Vec<String> = std::env::args().collect();
    for w in args.windows(2) {
        if w[0] == "--json" {
            return PathBuf::from(&w[1]);
        }
    }
    PathBuf::from("BENCH_interp.json")
}

fn main() {
    let w = interp_bench::workload().expect("corpus compiles + predecodes");
    println!(
        "corpus: {} programs, {} direct / {} emulated instructions per pass",
        w.corpus.programs.len(),
        w.direct_insts,
        w.emulated_insts
    );

    let b = interp_bench::measure(&w);
    b.report();
    println!("\n{}", interp_bench::render(&b));

    // Perf trajectory lands on disk before the assertions run, so a
    // regression still records its numbers.
    let path = json_path();
    b.write_json(&path).expect("write bench json");
    println!("wrote {}", path.display());

    interp_bench::assert_interp(&b).expect("interpreter throughput floors");
    println!(
        "interp assertions OK (decoded {:.1}x legacy on the emulated corpus)",
        interp_bench::speedup(&b).unwrap()
    );
}
