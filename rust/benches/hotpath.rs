//! Bench: the Monte-Carlo latency hot path — AOT XLA kernel vs the
//! native rust evaluation, across batch sizes (the §Perf batch-size
//! sweep in EXPERIMENTS.md comes from this bench).

use memclos::emulation::{EmulationSetup, TopologyKind};
use memclos::runtime::{ArtifactSet, LatencyEngine};
use memclos::util::bench::{black_box, Bench};
use memclos::util::rng::Rng;

fn main() {
    let setup = EmulationSetup::default_tech(TopologyKind::Clos, 4096, 128, 4095).unwrap();
    let params = setup.kernel_params();
    let space = setup.map.space_words();
    let mut rng = Rng::new(42);

    let mut b = Bench::new("hotpath");

    // Native evaluation at the default batch.
    let mut addrs = vec![0i32; 65_536];
    rng.fill_addresses(space, &mut addrs);
    let mut out = Vec::new();
    b.iter("native-65536", || {
        setup.native_batch(&addrs, &mut out);
        black_box(out.len())
    });
    b.iter("exact-closed-form", || black_box(setup.expected_latency()));

    // XLA engine across lowered batch sizes.
    match ArtifactSet::new() {
        Ok(set) => {
            for batch in [4096usize, 16_384, 65_536, 262_144] {
                let name = format!("latency_batch_{batch}");
                if !set.available(&name) {
                    eprintln!("(skipping {name}: artifact missing)");
                    continue;
                }
                let engine = LatencyEngine::load(&set, batch).unwrap();
                let mut buf = vec![0i32; batch];
                rng.fill_addresses(space, &mut buf);
                let label = format!("xla-{batch}");
                b.iter(&label, || {
                    let (_, mean) = engine.run(&buf, &params).unwrap();
                    black_box(mean)
                });
                let label = format!("xla-mean-{batch}");
                b.iter(&label, || black_box(engine.run_mean(&buf, &params).unwrap()));
            }
        }
        Err(e) => eprintln!("(no PJRT client: {e})"),
    }

    b.report();

    // Throughput summary: addresses per second per path.
    println!("\nthroughput (addresses/s):");
    for m in b.results() {
        let batch: f64 = match m.name.as_str() {
            "native-65536" => 65_536.0,
            s if s.starts_with("xla-") => s[4..].parse().unwrap_or(0.0),
            _ => continue,
        };
        println!("  {:<14} {:>12.0}", m.name, batch / m.median.as_secs_f64());
    }
}
