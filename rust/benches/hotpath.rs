//! Bench: the emulated-memory access hot path across every layer that
//! serves it — rank-LUT batch vs the seed's route-per-access
//! reference, the exact closed form, the DES (next-hop + port-arena
//! walk), the interpreter's channel-protocol loads, and the AOT XLA
//! kernel across lowered batch sizes (driven through the
//! `memclos::api` backends).
//!
//! Writes the machine-readable perf trajectory to `BENCH_hotpath.json`
//! (override the path with `--json PATH`; schema in
//! [`memclos::util::bench::Bench::to_json`]) and then enforces the
//! throughput floors: the LUT path must be >= 10x the routed reference
//! at the 65,536-address batch on the 4,096-tile Clos design point.
//!
//! Quick smoke mode: set `MEMCLOS_BENCH_QUICK=1` (what
//! `rust/scripts/bench_hotpath.sh` does).

use std::path::PathBuf;

use memclos::api::{AddrStream, LatencyBackend, XlaBackend};
use memclos::figures::hotpath;
use memclos::util::bench::black_box;
use memclos::util::rng::Rng;

fn json_path() -> PathBuf {
    let args: Vec<String> = std::env::args().collect();
    for w in args.windows(2) {
        if w[0] == "--json" {
            return PathBuf::from(&w[1]);
        }
    }
    PathBuf::from("BENCH_hotpath.json")
}

fn main() {
    let setup = hotpath::design_point().unwrap();
    let mut rng = Rng::new(42);

    // Native + DES + interpreter paths (shared with `memclos
    // bench-hotpath`).
    let mut b = hotpath::measure(&setup);

    // XLA backend across lowered batch sizes: the mean path (what the
    // sweep hot loop runs) and the full per-address latency vector.
    for batch in [4096usize, 16_384, 65_536, 262_144] {
        let backend = match XlaBackend::load(batch) {
            Ok(be) => be,
            Err(e) => {
                eprintln!("(skipping xla batch {batch}: {e})");
                continue;
            }
        };
        // NOTE: `xla-eval-{batch}` times the full api path (address
        // generation + run_mean), deliberately NOT named `xla-{batch}`
        // so it cannot be diffed against a differently-scoped case.
        let seed = rng.next_u64();
        b.iter_items(&format!("xla-eval-{batch}"), batch as u64, || {
            let eval = backend
                .evaluate(&setup, &AddrStream::new(batch, seed))
                .expect("xla evaluate");
            black_box(eval.mean_cycles)
        });
        let mut buf = vec![0i32; batch];
        rng.fill_addresses(setup.map.space_words(), &mut buf);
        b.iter_items(&format!("xla-latencies-{batch}"), batch as u64, || {
            let (lat, mean) = backend.batch_latencies(&setup, &buf).expect("xla batch");
            black_box((lat.len(), mean))
        });
    }

    b.report();

    // Throughput summary: addresses per second per path.
    println!("\nthroughput (addresses/s):");
    for m in b.results() {
        if m.items > 0 {
            println!("  {:<20} {:>14.0}", m.name, m.throughput());
        }
    }
    println!("\n{}", hotpath::render(&setup, &b));

    // Perf trajectory lands on disk before the assertions run, so a
    // regression still records its numbers.
    let path = json_path();
    b.write_json(&path).expect("write bench json");
    println!("wrote {}", path.display());

    hotpath::assert_hotpath(&b).expect("hot-path throughput floors");
    println!("throughput assertions OK (LUT {:.1}x routed)", hotpath::lut_speedup(&b).unwrap());
}
