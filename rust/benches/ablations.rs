//! Bench: design-choice ablations (route-open, clock scaling, switch
//! degree, eDRAM tiles) + the multi-client contention extension.

use memclos::api::{DesignPoint, Tech};
use memclos::figures::ablations;
use memclos::sim::network::run_contention;
use memclos::util::bench::Bench;
use memclos::util::table::{f, Table};

fn main() {
    let tech = Tech::default();
    let rows = ablations::generate(&tech).expect("ablations");
    println!("{}", ablations::render(&rows));

    // Contention extension: latency inflation vs concurrent clients
    // (what §6.3 abstracts as c_cont; zero load == sequential program).
    let setup = DesignPoint::clos(256).mem_kb(128).k(255).build().unwrap();
    let mut t = Table::new(&["clients", "mean latency cy", "inflation"])
        .with_title("Contention extension (256-tile folded Clos, random accesses)");
    for clients in [1usize, 2, 4, 8, 16, 32] {
        let r = run_contention(&setup, clients, 400, 9);
        t.row(&[
            clients.to_string(),
            f(r.latency.mean(), 1),
            f(r.inflation, 3),
        ]);
    }
    println!("{}", t.render());

    let mut b = Bench::new("ablations");
    b.iter("generate-all", || ablations::generate(&tech).unwrap());
    b.iter("contention-16x400", || run_contention(&setup, 16, 400, 9).inflation);
    b.report();
}
