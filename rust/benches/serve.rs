//! Bench: the serve layer's hot path — frame codec throughput, request
//! parse+render, and `Service::handle` cold (fresh canonical keys) vs
//! hot (cache hits) on the exact backend.
//!
//! Writes the machine-readable perf trajectory to `BENCH_serve.json`
//! (override with `--json PATH`; same schema family as
//! `BENCH_hotpath.json`, emitted by `rust/scripts/bench_hotpath.sh`,
//! uploaded by CI) and finishes with the bit-identity smoke: a cache
//! hit must return byte-identical payload to the cold evaluation.
//!
//! Quick smoke mode: set `MEMCLOS_BENCH_QUICK=1` (what
//! `rust/scripts/bench_hotpath.sh` does).

use std::io::Cursor;
use std::path::PathBuf;
use std::time::Duration;

use memclos::api::Mode;
use memclos::serve::proto::Request;
use memclos::serve::service::{ServeConfig, Service};
use memclos::serve::{read_frame, write_frame};
use memclos::util::bench::{black_box, Bench};

fn json_path() -> PathBuf {
    let args: Vec<String> = std::env::args().collect();
    for w in args.windows(2) {
        if w[0] == "--json" {
            return PathBuf::from(&w[1]);
        }
    }
    PathBuf::from("BENCH_serve.json")
}

const REQ: &str =
    "{\"id\": 7, \"kind\": \"latency\", \"tiles\": 1024, \"k\": 255, \"mem_kb\": 128, \"seed\": 3}";

fn main() {
    let mut b = Bench::new("serve");

    // Frame codec round trip (frames/s) on a request-sized payload.
    b.iter_items("frame-roundtrip", 1, || {
        let mut wire = Vec::with_capacity(REQ.len() + 4);
        write_frame(&mut wire, REQ.as_bytes()).expect("encode");
        black_box(read_frame(&mut Cursor::new(wire)).expect("decode").expect("one frame").len())
    });

    // Request parse + canonicalise + render (requests/s).
    b.iter_items("request-parse-render", 1, || {
        let req = Request::from_bytes(REQ.as_bytes()).expect("parse");
        black_box(req.to_json().render().len())
    });

    // Service::handle — cold path: a fresh canonical key every call
    // (rotating seeds defeat the cache), exact backend, no batching.
    let svc = Service::new(ServeConfig {
        mode: Mode::Exact,
        batch_max: 1,
        jobs: 1,
        linger: Duration::from_micros(0),
        ..ServeConfig::default()
    });
    let mut seed = 0u64;
    b.iter_items("handle-cold", 1, || {
        seed += 1;
        let body = format!(
            "{{\"kind\": \"latency\", \"tiles\": 256, \"k\": 63, \"mem_kb\": 64, \"seed\": {seed}}}"
        );
        let req = Request::from_bytes(body.as_bytes()).expect("parse");
        black_box(svc.handle(&req).expect("evaluates").len())
    });

    // Service::handle — hot path: one canonical key, all cache hits.
    let hot = Request::from_bytes(REQ.as_bytes()).expect("parse");
    let cold_payload = svc.handle(&hot).expect("first evaluation");
    b.iter_items("handle-hot", 1, || black_box(svc.handle(&hot).expect("cache hit").len()));

    b.report();
    println!("\nthroughput (items/s):");
    for m in b.results() {
        if m.items > 0 {
            println!("  {:<24} {:>14.0}", m.name, m.throughput());
        }
    }

    let path = json_path();
    b.write_json(&path).expect("write bench json");
    println!("wrote {}", path.display());

    // Bit-identity smoke: the hot path must serve the cold bytes.
    let hit = svc.handle(&hot).expect("cache hit");
    assert_eq!(*cold_payload, *hit, "cache hit diverged from the evaluation");
    let stats = svc.stats();
    assert!(stats.cache.hits > 0 && stats.cache.misses > 0, "{stats:?}");
    println!(
        "bit-identity smoke OK ({} hits / {} misses)",
        stats.cache.hits, stats.cache.misses
    );
}
