//! Bench: regenerate paper Fig 6 (component area share) and time it.

use memclos::figures::fig6;
use memclos::tech::ChipTech;
use memclos::util::bench::Bench;

fn main() {
    let tech = ChipTech::default();
    let rows = fig6::generate(&tech).expect("fig6");
    println!("{}", fig6::render(&rows));

    let mut b = Bench::new("fig6");
    b.iter("generate", || fig6::generate(&tech).unwrap());
    b.report();
}
