//! Bench: regenerate paper Fig 9 (absolute emulated-memory latency vs
//! emulation size) end-to-end — the production path uses the AOT XLA
//! kernel when `artifacts/` exists; the exact native model otherwise.
//! Both are timed for comparison.

use memclos::coordinator::EvalMode;
use memclos::figures::{fig9, FigOpts};
use memclos::util::bench::Bench;

fn main() {
    let auto = FigOpts::auto();
    let fig = fig9::generate(&auto).expect("fig9");
    println!("{}", fig9::render(&fig));
    println!("(mode: {:?})\n", auto.mode);

    let mut b = Bench::new("fig9");
    let exact = FigOpts { mode: EvalMode::Exact, ..FigOpts::default() };
    b.iter("generate-exact", || fig9::generate(&exact).unwrap());
    if matches!(auto.mode, EvalMode::XlaMc { .. }) {
        let xla = FigOpts { mode: EvalMode::XlaMc { samples: 65_536, batch: 16_384 }, ..auto };
        b.iter("generate-xla-16k-batches", || fig9::generate(&xla).unwrap());
    }
    b.report();
}
