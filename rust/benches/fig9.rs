//! Bench: regenerate paper Fig 9 (absolute emulated-memory latency vs
//! emulation size) end-to-end — the production path uses the AOT XLA
//! kernel when `artifacts/` exists; native Monte-Carlo otherwise.
//! Both are timed against the exact closed form.

use memclos::api::{xla_ready, Mode};
use memclos::figures::{fig9, FigOpts};
use memclos::util::bench::Bench;

fn main() {
    let auto = FigOpts::auto();
    let fig = fig9::generate(&auto).expect("fig9");
    println!("{}", fig9::render(&fig));
    let resolved = if xla_ready(16_384) { "xla" } else { "native" };
    println!("(mode: {:?} -> {resolved})\n", auto.mode);

    let mut b = Bench::new("fig9");
    let exact = FigOpts { mode: Mode::Exact, ..FigOpts::default() };
    b.iter("generate-exact", || fig9::generate(&exact).unwrap());
    if xla_ready(16_384) {
        let xla = FigOpts { mode: Mode::Xla { samples: 65_536, batch: 16_384 }, ..auto };
        b.iter("generate-xla-16k-batches", || fig9::generate(&xla).unwrap());
    }
    b.report();
}
