//! Bench: regenerate the §7.3 binary-size comparison over the miniC
//! corpus and time full corpus compilation with both backends.

use memclos::cc::{compile, corpus, Backend};
use memclos::figures::binary_size;
use memclos::util::bench::Bench;

fn main() {
    let rows = binary_size::generate().expect("binary_size");
    println!("{}", binary_size::render(&rows));

    let mut b = Bench::new("binary_size");
    b.iter("compile-corpus-direct", || {
        corpus::all()
            .iter()
            .map(|p| compile(p.source, Backend::Direct).unwrap().binary_bytes())
            .sum::<usize>()
    });
    b.iter("compile-corpus-emulated", || {
        corpus::all()
            .iter()
            .map(|p| compile(p.source, Backend::Emulated).unwrap().binary_bytes())
            .sum::<usize>()
    });
    b.report();
}
