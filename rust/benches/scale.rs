//! Bench: the scale trajectory of the computed-routing machinery —
//! topology + O(V) router build time and the uncontended DES
//! dependent-chain throughput at 1K / 64K / 1M tiles.
//!
//! Writes the machine-readable results to `BENCH_scale.json` (override
//! with `--json PATH`; schema in
//! [`memclos::util::bench::Bench::to_json`]), then enforces the hard
//! memory ceiling: at a million tiles the computed router must stay
//! under 8 MiB and [`RoutingTable::try_build`] must refuse the graph
//! with the typed [`TableTooLarge`] error — so the O(n²) table can
//! never silently return to the hot path.
//!
//! Quick smoke mode: set `MEMCLOS_BENCH_QUICK=1` (what
//! `rust/scripts/bench_hotpath.sh` does).

use std::path::PathBuf;

use memclos::api::DesignPoint;
use memclos::sim::NetworkSim;
use memclos::topology::{
    ClosSpec, FoldedClos, Mesh2D, MeshSpec, RoutingTable, Topology, MAX_TABLE_SWITCHES,
};
use memclos::util::bench::{black_box, Bench};
use memclos::util::rng::Rng;

/// The sizes the trajectory tracks: the paper's entry point, the old
/// table ceiling's first casualty, and the million-tile headline.
const SIZES: &[usize] = &[1 << 10, 1 << 16, 1 << 20];

/// Dependent accesses per timed iteration of the DES chain.
const CHAIN: usize = 4096;

fn json_path() -> PathBuf {
    let args: Vec<String> = std::env::args().collect();
    for w in args.windows(2) {
        if w[0] == "--json" {
            return PathBuf::from(&w[1]);
        }
    }
    PathBuf::from("BENCH_scale.json")
}

fn main() {
    let mut b = Bench::new("scale");

    // Topology + computed-router construction, end to end. The graph
    // dominates; the router itself is one O(V) prefix-sum pass.
    for &tiles in SIZES {
        b.iter(&format!("build-clos-{tiles}"), || {
            let topo = Topology::Clos(FoldedClos::build(ClosSpec::with_tiles(tiles)).unwrap());
            black_box(topo.next_hops().memory_bytes())
        });
        b.iter(&format!("build-mesh-{tiles}"), || {
            let topo = Topology::Mesh(Mesh2D::build(MeshSpec::with_tiles(tiles)).unwrap());
            black_box(topo.next_hops().memory_bytes())
        });
    }

    // The DesBackend loop: one client's causally-dependent accesses in
    // uncontended mode (analytic fast path, bit-identical to the walk).
    for &tiles in SIZES {
        let setup = DesignPoint::clos(tiles).build().unwrap();
        let mut rng = Rng::new(0x5CA1E ^ tiles as u64);
        let space = setup.map.space_words();
        let dests: Vec<usize> =
            (0..CHAIN).map(|_| setup.map.tile_of(rng.below(space))).collect();
        let client = setup.map.client;
        let mut sim = NetworkSim::uncontended(&setup.topo, &setup.model);
        let mut now = 0u64;
        b.iter_items(&format!("des-chain-clos-{tiles}"), CHAIN as u64, || {
            for &t in &dests {
                now = sim.access(client, t, now);
            }
            black_box(now)
        });
    }

    b.report();
    println!("\nthroughput (addresses/s):");
    for m in b.results() {
        if m.items > 0 {
            println!("  {:<24} {:>14.0}", m.name, m.throughput());
        }
    }

    // The trajectory lands on disk before the assertions run, so a
    // regression still records its numbers.
    let path = json_path();
    b.write_json(&path).expect("write bench json");
    println!("wrote {}", path.display());

    // The hard memory ceiling. If someone reintroduces an O(n²)
    // structure on the healthy routing path, memory_bytes blows the
    // 8 MiB budget (a million-tile table would need ~340 GB) and this
    // bench fails loudly.
    let million = 1usize << 20;
    let clos = Topology::Clos(FoldedClos::build(ClosSpec::with_tiles(million)).unwrap());
    let mesh = Topology::Mesh(Mesh2D::build(MeshSpec::with_tiles(million)).unwrap());
    for topo in [&clos, &mesh] {
        let routes = topo.next_hops();
        assert!(
            !routes.is_table(),
            "{}: the million-tile router fell back to the dense table",
            topo.name()
        );
        assert!(
            routes.memory_bytes() < 8 << 20,
            "{}: router memory {} bytes breaks the 8 MiB ceiling",
            topo.name(),
            routes.memory_bytes()
        );
        assert!(routes.switches() > MAX_TABLE_SWITCHES);
        // And the table itself stays a typed refusal at this size.
        let err = RoutingTable::try_build(topo.graph()).unwrap_err();
        println!(
            "{}: {} switches, router {} KiB, dense table refused ({err})",
            topo.name(),
            routes.switches(),
            routes.memory_bytes() / 1024
        );
    }
    println!("memory-ceiling assertions OK");
}
