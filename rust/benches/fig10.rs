//! Bench: regenerate paper Fig 10 (benchmark slowdown vs emulation
//! size) and time the sweep.

use memclos::figures::{fig10, FigOpts};
use memclos::util::bench::Bench;

fn main() {
    let opts = FigOpts::auto();
    let rows = fig10::generate(&opts).expect("fig10");
    println!("{}", fig10::render(&rows));

    let mut b = Bench::new("fig10");
    let exact = FigOpts::default();
    b.iter("generate-exact", || fig10::generate(&exact).unwrap());
    b.report();
}
