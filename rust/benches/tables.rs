//! Bench: regenerate the paper's parameter tables (Tables 1-5).

use memclos::figures::tables;
use memclos::util::bench::Bench;

fn main() {
    print!("{}", tables::render_all());

    let mut b = Bench::new("tables");
    b.iter("render-all", tables::render_all);
    b.report();
}
