//! Bench: regenerate the paper's parameter tables (Tables 1-5).

use memclos::api::Tech;
use memclos::figures::tables;
use memclos::util::bench::Bench;

fn main() {
    let tech = Tech::default();
    print!("{}", tables::render_all(&tech));

    let mut b = Bench::new("tables");
    b.iter("render-all", || tables::render_all(&tech));
    b.report();
}
