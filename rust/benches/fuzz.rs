//! Bench: the generative fuzzing + snapshot subsystem — case
//! generation/rendering throughput, the full differential check
//! (legacy vs fast on both backends), and the snapshot
//! serialise → parse → rebuild round trip.
//!
//! Writes the machine-readable perf trajectory to `BENCH_fuzz.json`
//! (override with `--json PATH`; same schema family as
//! `BENCH_hotpath.json`, emitted by `rust/scripts/bench_hotpath.sh`,
//! uploaded by CI) and then runs the oracle smoke: a bounded fuzz run
//! must be divergence-free and the snapshot-slice oracle must pass on
//! its sampled cases.
//!
//! Quick smoke mode: set `MEMCLOS_BENCH_QUICK=1` (what
//! `rust/scripts/bench_hotpath.sh` does).

use std::path::PathBuf;

use memclos::cc::{compile, corpus, Backend};
use memclos::emulation::{EmulationSetup, TopologyKind};
use memclos::isa::decode::predecode;
use memclos::isa::interp::{EmulatedChannelMemory, MachineState};
use memclos::isa::snapshot::{
    program_fingerprint, rebuild_memory, run_fast_slice, BackendSnap, Snapshot, Tier,
};
use memclos::util::bench::{black_box, Bench};
use memclos::workload::fuzzgen::{self, DiffHarness, FuzzConfig};

fn json_path() -> PathBuf {
    let args: Vec<String> = std::env::args().collect();
    for w in args.windows(2) {
        if w[0] == "--json" {
            return PathBuf::from(&w[1]);
        }
    }
    PathBuf::from("BENCH_fuzz.json")
}

fn main() {
    let quick = std::env::var("MEMCLOS_BENCH_QUICK").is_ok();
    let mut b = Bench::new("fuzz");

    // Generation + rendering throughput (the pure-CPU side of a fuzz
    // campaign; no execution).
    const GEN_BATCH: u64 = 64;
    let mut gen_index = 0u64;
    b.iter_items("generate-render", GEN_BATCH, || {
        let mut bytes = 0usize;
        for _ in 0..GEN_BATCH {
            bytes += fuzzgen::render(&fuzzgen::generate(0xBE7C, gen_index)).len();
            gen_index += 1;
        }
        black_box(bytes)
    });

    // Full differential check throughput: compile on both backends,
    // run every tier, compare stats/registers/errors.
    let harness = DiffHarness::new().expect("harness build");
    let sources: Vec<String> =
        (0..16).map(|i| fuzzgen::render(&fuzzgen::generate(0xD1FF, i))).collect();
    b.iter_items("diff-check", sources.len() as u64, || {
        let mut clean = 0usize;
        for src in &sources {
            if harness.check_source(src).is_ok() {
                clean += 1;
            }
        }
        assert_eq!(clean, sources.len(), "bench corpus must be divergence-free");
        black_box(clean)
    });

    // Snapshot round trip on a genuine paused run: serialise, parse,
    // verify, rebuild the memory, all in one measured unit.
    let prog = corpus::all().into_iter().find(|p| p.name == "sieve").unwrap();
    let compiled = compile(prog.source, Backend::Emulated).unwrap();
    let decoded = predecode(&compiled.code).unwrap();
    let setup = EmulationSetup::default_tech(TopologyKind::Clos, 256, 64, 128).unwrap();
    let snap = {
        let mut mem = EmulatedChannelMemory::new(setup);
        let blank = MachineState { local: vec![0i64; 1 << 16], ..MachineState::default() };
        let part = run_fast_slice(&decoded, &mut mem, &blank, 50_000_000, Some(400));
        assert_eq!(part.outcome, Ok(false), "sieve must pause at 400 cycles");
        Snapshot {
            tier: Tier::Fast,
            backend: BackendSnap::of_emulated(&mem),
            space_words: mem.setup().map.space_words(),
            max_steps: 50_000_000,
            program: "sieve".into(),
            program_fnv: program_fingerprint(&compiled.code),
            state: part.state,
            pages: Snapshot::pages_of(mem.store()),
        }
    };
    let blob = snap.to_bytes();
    b.iter("snapshot-save", || black_box(snap.to_bytes().len()));
    b.iter("snapshot-restore", || {
        let parsed = Snapshot::from_bytes(&blob).expect("round trip");
        let mem = rebuild_memory(&parsed).expect("rebuild");
        black_box((parsed.state.stats.cycles, std::mem::size_of_val(&mem)))
    });

    b.report();
    println!("\nthroughput (items/s):");
    for m in b.results() {
        if m.items > 0 {
            println!("  {:<24} {:>14.0}", m.name, m.throughput());
        }
    }

    // Perf trajectory lands on disk before the assertions run, so a
    // regression still records its numbers.
    let path = json_path();
    b.write_json(&path).expect("write bench json");
    println!("wrote {}", path.display());

    // Oracle smoke: a bounded fuzz campaign (differential + snapshot
    // slices) is divergence-free, and the resumed slice from the blob
    // above finishes with the corpus-expected result.
    let cases = if quick { 64 } else { 256 };
    let cfg = FuzzConfig { out_dir: None, ..FuzzConfig::new(0, cases) };
    let summary = fuzzgen::run_fuzz(&cfg).expect("fuzz run");
    assert_eq!(summary.cases, cases, "early stop means a divergence");
    assert!(
        summary.failures.is_empty(),
        "divergences in the smoke run: {}",
        summary.failures.len()
    );
    assert!(summary.snapshot_checks > 0, "snapshot oracle must sample cases");
    let parsed = Snapshot::from_bytes(&blob).unwrap();
    let mut mem = rebuild_memory(&parsed).unwrap();
    let done = run_fast_slice(&decoded, mem.as_dyn(), &parsed.state, parsed.max_steps, None);
    assert_eq!(done.outcome, Ok(true), "resume must halt");
    assert_eq!(done.state.regs[0], prog.expected.unwrap(), "resumed sieve result");
    println!(
        "oracle smoke OK ({} cases, {} snapshot slices, 0 divergences)",
        summary.cases, summary.snapshot_checks
    );
}
