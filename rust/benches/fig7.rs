//! Bench: regenerate paper Fig 7 (interposer area) and time it.

use memclos::figures::fig7;
use memclos::tech::{ChipTech, InterposerTech};
use memclos::util::bench::Bench;

fn main() {
    let chip = ChipTech::default();
    let ip = InterposerTech::default();
    let rows = fig7::generate(&chip, &ip).expect("fig7");
    println!("{}", fig7::render(&rows));

    let mut b = Bench::new("fig7");
    b.iter("generate", || fig7::generate(&chip, &ip).unwrap());
    b.report();
}
