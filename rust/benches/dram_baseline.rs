//! Bench: the §6.1 DDR3 baseline measurement (paper: 35 ns single
//! rank, 36 ns multi-rank) and the simulator's throughput.

use memclos::dram::{measure_random_latency, DramConfig};
use memclos::util::bench::Bench;

fn main() {
    println!("DDR3-1600 random-access latency (one transaction at a time):");
    for ranks in [1usize, 2, 4, 8, 16] {
        let m = measure_random_latency(DramConfig::with_ranks(ranks), 20_000, 7).unwrap();
        println!(
            "  {ranks:>2} rank(s) / {:>2} GB: {:.2} ns avg (sd {:.2})",
            m.config.capacity_bytes() >> 30,
            m.avg_ns,
            m.stddev_ns
        );
    }

    let mut b = Bench::new("dram_baseline");
    b.iter("20k-accesses-1rank", || {
        measure_random_latency(DramConfig::with_ranks(1), 20_000, 7).unwrap().avg_ns
    });
    b.iter("20k-accesses-4rank", || {
        measure_random_latency(DramConfig::with_ranks(4), 20_000, 7).unwrap().avg_ns
    });
    b.report();
}
